//! Length-prefixed binary event journal: the durable form of the engine's
//! [`EventSink`] stream.
//!
//! The format follows the wire protocol's v2 codec idioms (`PROTOCOL.md`
//! appendix B): little-endian fixed-width fields, one `u32` length prefix
//! per record, tagged unions, range/list task-set encoding, and a
//! bounds-checked reader that rejects trailing garbage.  Deliberately *not*
//! recorded: wall-clock timestamps or anything else nondeterministic, so a
//! seeded simulator run produces a byte-identical journal on every
//! execution (pinned by `tests/obs.rs` and the CI `journal-determinism`
//! step).
//!
//! The journal is a *differential oracle*: [`replay_stats`] folds the
//! recorded events back into a [`MasterStats`] that must equal the live
//! run's counters (the chaos harness checks this with `--journal-oracle`),
//! and [`super::replay_trace`] rebuilds the per-chunk [`crate::trace::Trace`].
//! It is also the write-ahead log behind crash recovery:
//! [`crate::coordinator::Engine::replay`] reconstructs the exact engine
//! state from a journal (optionally from a snapshot plus the journal
//! suffix), which is how `rdlb serve --resume` survives a master `kill -9`.

use anyhow::{bail, ensure, Result};

use crate::coordinator::{
    Assignment, Effect, EngineEvent, EventSink, MasterStats, ResultNotes, TaskSet,
};

/// File magic: identifies a journal regardless of extension.
pub const JOURNAL_MAGIC: [u8; 8] = *b"RDLBJRNL";
/// Journal format version (bumped on any encoding change).
/// v2: worker-health records — `HealthTick` / `Progress` events and the
/// `Overdue` effect.
pub const JOURNAL_VERSION: u16 = 2;
/// Upper bound on one record's payload — same defensive cap as the wire
/// protocol's `MAX_FRAME_LEN`.
pub const MAX_RECORD_LEN: u32 = 32 << 20;

// Event tags.
const EV_REQUEST: u8 = 0x01;
const EV_RESULT: u8 = 0x02;
const EV_DISCONNECTED: u8 = 0x03;
const EV_REFUSED: u8 = 0x04;
const EV_TIMEOUT: u8 = 0x05;
const EV_HEALTH_TICK: u8 = 0x06;
const EV_PROGRESS: u8 = 0x07;

// Effect tags.
const EF_ASSIGN: u8 = 0x10;
const EF_PARK: u8 = 0x11;
const EF_WAKE: u8 = 0x12;
const EF_TERMINATE: u8 = 0x13;
const EF_COMPLETED: u8 = 0x14;
const EF_OVERDUE: u8 = 0x15;

// Task-set kinds (same values as the wire protocol).
const TS_RANGE: u8 = 0x00;
const TS_LIST: u8 = 0x01;

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn push_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_task_set(buf: &mut Vec<u8>, ts: &TaskSet) {
    match ts {
        TaskSet::Range { start, end } => {
            buf.push(TS_RANGE);
            push_u32(buf, *start);
            push_u32(buf, *end);
        }
        TaskSet::List(ids) => {
            buf.push(TS_LIST);
            push_u32(buf, ids.len() as u32);
            for id in ids {
                push_u32(buf, *id);
            }
        }
    }
}

fn push_effect(buf: &mut Vec<u8>, eff: &Effect) {
    match eff {
        Effect::Assign(a) => {
            buf.push(EF_ASSIGN);
            push_u64(buf, a.id);
            push_u32(buf, a.worker as u32);
            buf.push(a.rescheduled as u8);
            push_task_set(buf, &a.tasks);
        }
        Effect::Park { worker } => {
            buf.push(EF_PARK);
            push_u32(buf, *worker as u32);
        }
        Effect::Wake { worker } => {
            buf.push(EF_WAKE);
            push_u32(buf, *worker as u32);
        }
        Effect::TerminateWorker { worker } => {
            buf.push(EF_TERMINATE);
            push_u32(buf, *worker as u32);
        }
        Effect::Completed => buf.push(EF_COMPLETED),
        Effect::Overdue { worker, assignment_id, quarantined } => {
            buf.push(EF_OVERDUE);
            push_u32(buf, *worker as u32);
            push_u64(buf, *assignment_id);
            buf.push(*quarantined as u8);
        }
    }
}

/// Encode one record (payload into `scratch`, then length-prefixed into
/// `buf`) — the scratch-buffer style of the v2 protocol codec.
fn encode_record(
    buf: &mut Vec<u8>,
    scratch: &mut Vec<u8>,
    scope: u32,
    now: f64,
    event: &EngineEvent<'_>,
    effects: &[Effect],
    notes: &ResultNotes,
) {
    scratch.clear();
    match event {
        EngineEvent::WorkerRequest { worker } => {
            scratch.push(EV_REQUEST);
            push_u32(scratch, scope);
            push_f64(scratch, now);
            push_u32(scratch, *worker as u32);
        }
        EngineEvent::ResultReceived { worker, assignment_id, compute_secs, digests } => {
            scratch.push(EV_RESULT);
            push_u32(scratch, scope);
            push_f64(scratch, now);
            push_u32(scratch, *worker as u32);
            push_u64(scratch, *assignment_id);
            push_f64(scratch, *compute_secs);
            // Digest *values* are not journaled (they are the computed
            // application output, not scheduling state); the attributed sum
            // in the notes is enough for the oracle.
            push_u32(scratch, digests.len() as u32);
            scratch.push(notes.completed_chunks as u8);
            scratch.push(notes.rescheduled_completions as u8);
            scratch.push(notes.unknown_results as u8);
            push_u64(scratch, notes.first_completions);
            push_u64(scratch, notes.duplicate_iterations);
            push_f64(scratch, notes.digest_delta);
        }
        EngineEvent::WorkerDisconnected { worker } => {
            scratch.push(EV_DISCONNECTED);
            push_u32(scratch, scope);
            push_f64(scratch, now);
            push_u32(scratch, *worker as u32);
        }
        EngineEvent::VersionRefused { worker } => {
            scratch.push(EV_REFUSED);
            push_u32(scratch, scope);
            push_f64(scratch, now);
            push_u32(scratch, *worker as u32);
        }
        EngineEvent::Timeout => {
            scratch.push(EV_TIMEOUT);
            push_u32(scratch, scope);
            push_f64(scratch, now);
        }
        EngineEvent::HealthTick => {
            scratch.push(EV_HEALTH_TICK);
            push_u32(scratch, scope);
            push_f64(scratch, now);
        }
        EngineEvent::Progress { worker } => {
            scratch.push(EV_PROGRESS);
            push_u32(scratch, scope);
            push_f64(scratch, now);
            push_u32(scratch, *worker as u32);
        }
    }
    push_u32(scratch, effects.len() as u32);
    for eff in effects {
        push_effect(scratch, eff);
    }
    push_u32(buf, scratch.len() as u32);
    buf.extend_from_slice(scratch);
}

/// An in-memory [`EventSink`] that appends every record to a journal byte
/// buffer.  Runs are finite, so the whole journal is held in memory and
/// written out once at the end (the CLI's `--journal FILE`).
pub struct JournalSink {
    buf: Vec<u8>,
    scratch: Vec<u8>,
}

impl JournalSink {
    pub fn new() -> JournalSink {
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(&JOURNAL_MAGIC);
        push_u16(&mut buf, JOURNAL_VERSION);
        JournalSink { buf, scratch: Vec::with_capacity(256) }
    }

    /// The encoded journal so far (header + complete records).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Consume the sink, returning the encoded journal.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for JournalSink {
    fn default() -> Self {
        JournalSink::new()
    }
}

impl EventSink for JournalSink {
    fn record(
        &mut self,
        scope: u32,
        now: f64,
        event: &EngineEvent<'_>,
        effects: &[Effect],
        notes: &ResultNotes,
    ) {
        encode_record(&mut self.buf, &mut self.scratch, scope, now, event, effects, notes);
    }
}

/// Durable write-ahead [`EventSink`]: every record is appended to a file
/// with ONE `write_all` of `length ‖ payload` followed by `sync_data`, so a
/// `kill -9` at any instant can lose at most the tail record being appended
/// — never corrupt an earlier one — and every record the master *acted on*
/// is already on disk when the action's effects become visible to workers.
/// The torn-tail case is exactly what [`read_journal_tolerant`] absorbs on
/// `--resume`.
pub struct FileJournal {
    file: std::fs::File,
    record_buf: Vec<u8>,
    scratch: Vec<u8>,
    records: u64,
}

impl FileJournal {
    /// Start a fresh journal at `path` (truncating any existing file):
    /// header is written and fsynced before this returns.
    pub fn create(path: &std::path::Path) -> Result<FileJournal> {
        use std::io::Write;
        let mut file = std::fs::File::create(path)?;
        let mut header = Vec::with_capacity(10);
        header.extend_from_slice(&JOURNAL_MAGIC);
        push_u16(&mut header, JOURNAL_VERSION);
        file.write_all(&header)?;
        file.sync_data()?;
        Ok(FileJournal {
            file,
            record_buf: Vec::with_capacity(256),
            scratch: Vec::with_capacity(256),
            records: 0,
        })
    }

    /// Reopen `path` for appending after a crash: the file is truncated to
    /// `valid_len` (discarding a torn tail record, as reported by
    /// [`read_journal_tolerant`]) and the counter resumes at
    /// `existing_records`.
    pub fn append_after(
        path: &std::path::Path,
        valid_len: u64,
        existing_records: u64,
    ) -> Result<FileJournal> {
        use std::io::Seek;
        let mut file = std::fs::OpenOptions::new().read(true).write(true).open(path)?;
        file.set_len(valid_len)?;
        file.seek(std::io::SeekFrom::End(0))?;
        file.sync_data()?;
        Ok(FileJournal {
            file,
            record_buf: Vec::with_capacity(256),
            scratch: Vec::with_capacity(256),
            records: existing_records,
        })
    }

    /// Total complete records in the file (pre-crash + appended here).
    pub fn records(&self) -> u64 {
        self.records
    }
}

impl EventSink for FileJournal {
    fn record(
        &mut self,
        scope: u32,
        now: f64,
        event: &EngineEvent<'_>,
        effects: &[Effect],
        notes: &ResultNotes,
    ) {
        use std::io::Write;
        self.record_buf.clear();
        encode_record(&mut self.record_buf, &mut self.scratch, scope, now, event, effects, notes);
        // A write-ahead log that silently loses records is worse than a
        // crash: fail loudly so the operator sees durability is gone.
        self.file.write_all(&self.record_buf).expect("journal append failed");
        self.file.sync_data().expect("journal fsync failed");
        self.records += 1;
    }
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian reader (the protocol codec's idiom).
struct ByteReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(bytes: &'a [u8]) -> ByteReader<'a> {
        ByteReader { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.bytes.len(), "journal record truncated");
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Reject records with trailing garbage.
    fn finish(&self) -> Result<()> {
        ensure!(self.pos == self.bytes.len(), "trailing bytes in journal record");
        Ok(())
    }
}

/// The event half of a decoded record ([`EngineEvent`] without the borrowed
/// digest slice, which is not journaled).
#[derive(Debug, Clone, PartialEq)]
pub enum JournalEvent {
    Request { worker: usize },
    Result { worker: usize, assignment_id: u64, compute_secs: f64, digest_count: u32 },
    Disconnected { worker: usize },
    Refused { worker: usize },
    Timeout,
    HealthTick,
    Progress { worker: usize },
}

/// One decoded journal record: everything the sink observed for one event.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalRecord {
    /// Emitting engine: 0 = flat runtime / hierarchical root, `1 + g` =
    /// group `g`'s inner engine.
    pub scope: u32,
    /// Master clock when the event was handled.
    pub now: f64,
    pub event: JournalEvent,
    /// Per-result counter deltas (zero for non-result events).
    pub notes: ResultNotes,
    /// The effects this event appended, in order.
    pub effects: Vec<Effect>,
}

fn decode_task_set(r: &mut ByteReader<'_>) -> Result<TaskSet> {
    match r.u8()? {
        TS_RANGE => {
            let start = r.u32()?;
            let end = r.u32()?;
            ensure!(start <= end, "task range start {start} > end {end}");
            Ok(TaskSet::Range { start, end })
        }
        TS_LIST => {
            let count = r.u32()? as usize;
            ensure!(count <= MAX_RECORD_LEN as usize / 4, "task list too long");
            let mut ids = Vec::with_capacity(count);
            for _ in 0..count {
                ids.push(r.u32()?);
            }
            Ok(TaskSet::List(ids))
        }
        other => bail!("unknown task-set kind 0x{other:02x}"),
    }
}

fn decode_effect(r: &mut ByteReader<'_>) -> Result<Effect> {
    Ok(match r.u8()? {
        EF_ASSIGN => {
            let id = r.u64()?;
            let worker = r.u32()? as usize;
            let rescheduled = r.u8()? != 0;
            let tasks = decode_task_set(r)?;
            Effect::Assign(Assignment { id, worker, tasks, rescheduled })
        }
        EF_PARK => Effect::Park { worker: r.u32()? as usize },
        EF_WAKE => Effect::Wake { worker: r.u32()? as usize },
        EF_TERMINATE => Effect::TerminateWorker { worker: r.u32()? as usize },
        EF_COMPLETED => Effect::Completed,
        EF_OVERDUE => {
            let worker = r.u32()? as usize;
            let assignment_id = r.u64()?;
            let quarantined = r.u8()? != 0;
            Effect::Overdue { worker, assignment_id, quarantined }
        }
        other => bail!("unknown effect tag 0x{other:02x}"),
    })
}

fn decode_record(payload: &[u8]) -> Result<JournalRecord> {
    let mut r = ByteReader::new(payload);
    let tag = r.u8()?;
    let scope = r.u32()?;
    let now = r.f64()?;
    let mut notes = ResultNotes::default();
    let event = match tag {
        EV_REQUEST => JournalEvent::Request { worker: r.u32()? as usize },
        EV_RESULT => {
            let worker = r.u32()? as usize;
            let assignment_id = r.u64()?;
            let compute_secs = r.f64()?;
            let digest_count = r.u32()?;
            notes.completed_chunks = r.u8()? as u64;
            notes.rescheduled_completions = r.u8()? as u64;
            notes.unknown_results = r.u8()? as u64;
            notes.first_completions = r.u64()?;
            notes.duplicate_iterations = r.u64()?;
            notes.digest_delta = r.f64()?;
            JournalEvent::Result { worker, assignment_id, compute_secs, digest_count }
        }
        EV_DISCONNECTED => JournalEvent::Disconnected { worker: r.u32()? as usize },
        EV_REFUSED => JournalEvent::Refused { worker: r.u32()? as usize },
        EV_TIMEOUT => JournalEvent::Timeout,
        EV_HEALTH_TICK => JournalEvent::HealthTick,
        EV_PROGRESS => JournalEvent::Progress { worker: r.u32()? as usize },
        other => bail!("unknown event tag 0x{other:02x}"),
    };
    let n_effects = r.u32()? as usize;
    ensure!(n_effects <= MAX_RECORD_LEN as usize / 5, "effect list too long");
    let mut effects = Vec::with_capacity(n_effects);
    for _ in 0..n_effects {
        effects.push(decode_effect(&mut r)?);
    }
    r.finish()?;
    Ok(JournalRecord { scope, now, event, notes, effects })
}

/// Decode a complete journal (header + records).
pub fn read_journal(bytes: &[u8]) -> Result<Vec<JournalRecord>> {
    ensure!(bytes.len() >= 10, "journal shorter than its header");
    ensure!(bytes[..8] == JOURNAL_MAGIC, "not a journal (bad magic)");
    let version = u16::from_le_bytes(bytes[8..10].try_into().unwrap());
    ensure!(version == JOURNAL_VERSION, "unsupported journal version {version}");
    let mut records = Vec::new();
    let mut pos = 10usize;
    while pos < bytes.len() {
        ensure!(pos + 4 <= bytes.len(), "truncated record length at byte {pos}");
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        ensure!(len <= MAX_RECORD_LEN, "record length {len} exceeds cap");
        pos += 4;
        ensure!(pos + len as usize <= bytes.len(), "truncated record at byte {pos}");
        records.push(decode_record(&bytes[pos..pos + len as usize])?);
        pos += len as usize;
    }
    Ok(records)
}

/// Decode a journal that may end in a **torn tail record** — the one shape
/// of damage a `kill -9` can inflict on a [`FileJournal`], whose appends are
/// a single `write_all` + fsync.  Complete records are returned together
/// with the byte length of the valid prefix (`header ‖ complete records`),
/// which is what [`FileJournal::append_after`] truncates to on `--resume`.
/// Only tail truncation is tolerated: bad magic/version, an over-cap length
/// or an undecodable record that is fully present is still an error.
pub fn read_journal_tolerant(bytes: &[u8]) -> Result<(Vec<JournalRecord>, u64)> {
    ensure!(bytes.len() >= 10, "journal shorter than its header");
    ensure!(bytes[..8] == JOURNAL_MAGIC, "not a journal (bad magic)");
    let version = u16::from_le_bytes(bytes[8..10].try_into().unwrap());
    ensure!(version == JOURNAL_VERSION, "unsupported journal version {version}");
    let mut records = Vec::new();
    let mut pos = 10usize;
    loop {
        if pos + 4 > bytes.len() {
            break; // torn length prefix
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        ensure!(len <= MAX_RECORD_LEN, "record length {len} exceeds cap");
        if pos + 4 + len as usize > bytes.len() {
            break; // torn payload
        }
        records.push(decode_record(&bytes[pos + 4..pos + 4 + len as usize])?);
        pos += 4 + len as usize;
    }
    Ok((records, pos as u64))
}

// ---------------------------------------------------------------------------
// Replay oracle
// ---------------------------------------------------------------------------

/// Reconstruct the master's counters from a journal's **scope-0** records.
///
/// For any flat runtime — and for the hierarchical runtime, whose
/// `Outcome::stats` are the *root* engine's — the result must equal the
/// live run's `Outcome::stats` field for field.  The chaos harness arms
/// this as an invariant with `rdlb chaos --journal-oracle`.
pub fn replay_stats(records: &[JournalRecord]) -> MasterStats {
    let mut s = MasterStats::default();
    for rec in records {
        if rec.scope != 0 {
            continue;
        }
        match &rec.event {
            JournalEvent::Request { .. } => s.requests += 1,
            JournalEvent::Result { .. } => {
                s.completed_chunks += rec.notes.completed_chunks;
                s.finished_iterations += rec.notes.first_completions;
                s.duplicate_iterations += rec.notes.duplicate_iterations;
                s.rescheduled_completions += rec.notes.rescheduled_completions;
                s.unknown_results += rec.notes.unknown_results;
            }
            JournalEvent::Refused { .. } => s.refused_workers += 1,
            JournalEvent::Disconnected { .. }
            | JournalEvent::Timeout
            | JournalEvent::HealthTick
            | JournalEvent::Progress { .. } => {}
        }
        for eff in &rec.effects {
            match eff {
                Effect::Assign(a) => {
                    s.assigned_chunks += 1;
                    s.assigned_iterations += a.len() as u64;
                    if a.rescheduled {
                        s.rescheduled_chunks += 1;
                        s.rescheduled_iterations += a.len() as u64;
                    }
                }
                Effect::Overdue { quarantined, .. } => {
                    s.overdue_chunks += 1;
                    if *quarantined {
                        s.quarantined_workers += 1;
                    }
                }
                _ => {}
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_effects() -> Vec<Effect> {
        vec![
            Effect::Assign(Assignment {
                id: 7,
                worker: 3,
                tasks: TaskSet::Range { start: 10, end: 20 },
                rescheduled: false,
            }),
            Effect::Assign(Assignment {
                id: 8,
                worker: 1,
                tasks: TaskSet::List(vec![2, 5, 9]),
                rescheduled: true,
            }),
            Effect::Park { worker: 2 },
            Effect::Wake { worker: 2 },
            Effect::TerminateWorker { worker: 0 },
            Effect::Completed,
            Effect::Overdue { worker: 3, assignment_id: 7, quarantined: true },
        ]
    }

    #[test]
    fn round_trips_every_event_and_effect_kind() {
        let mut sink = JournalSink::new();
        let effects = sample_effects();
        let notes = ResultNotes {
            completed_chunks: 1,
            first_completions: 9,
            duplicate_iterations: 1,
            rescheduled_completions: 1,
            unknown_results: 0,
            digest_delta: 2.5,
        };
        let digests = [1.0, 2.0];
        let zero = ResultNotes::default();
        sink.record(0, 0.25, &EngineEvent::WorkerRequest { worker: 4 }, &effects[..1], &zero);
        sink.record(
            3,
            0.5,
            &EngineEvent::ResultReceived {
                worker: 1,
                assignment_id: 7,
                compute_secs: 0.125,
                digests: &digests,
            },
            &effects[2..4],
            &notes,
        );
        sink.record(0, 0.75, &EngineEvent::WorkerDisconnected { worker: 2 }, &[], &zero);
        sink.record(0, 0.8, &EngineEvent::VersionRefused { worker: 5 }, &effects[4..5], &zero);
        sink.record(0, 1.0, &EngineEvent::Timeout, &effects[5..6], &zero);
        sink.record(0, 1.25, &EngineEvent::HealthTick, &effects[6..], &zero);
        sink.record(0, 1.5, &EngineEvent::Progress { worker: 3 }, &[], &zero);

        let records = read_journal(sink.bytes()).unwrap();
        assert_eq!(records.len(), 7);
        assert_eq!(records[0].event, JournalEvent::Request { worker: 4 });
        assert_eq!(records[0].effects, effects[..1]);
        assert_eq!(records[1].scope, 3);
        assert_eq!(
            records[1].event,
            JournalEvent::Result {
                worker: 1,
                assignment_id: 7,
                compute_secs: 0.125,
                digest_count: 2
            }
        );
        assert_eq!(records[1].notes, notes);
        assert_eq!(records[1].effects, effects[2..4]);
        assert_eq!(records[2].event, JournalEvent::Disconnected { worker: 2 });
        assert_eq!(records[3].event, JournalEvent::Refused { worker: 5 });
        assert_eq!(records[3].effects, effects[4..5]);
        assert_eq!(records[4].event, JournalEvent::Timeout);
        assert_eq!(records[4].effects, effects[5..6]);
        assert_eq!(records[5].event, JournalEvent::HealthTick);
        assert_eq!(records[5].effects, effects[6..]);
        assert_eq!(records[6].event, JournalEvent::Progress { worker: 3 });
        assert!(records[6].effects.is_empty());
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        assert!(read_journal(b"NOTAJRNL\x01\x00").is_err());
        assert!(read_journal(&JOURNAL_MAGIC).is_err(), "header alone is too short");
        let mut wrong_version = JOURNAL_MAGIC.to_vec();
        wrong_version.extend_from_slice(&99u16.to_le_bytes());
        assert!(read_journal(&wrong_version).is_err());
        // Truncate a valid journal mid-record.
        let mut sink = JournalSink::new();
        sink.record(0, 0.0, &EngineEvent::WorkerRequest { worker: 0 }, &[], &Default::default());
        let bytes = sink.into_bytes();
        assert!(read_journal(&bytes[..bytes.len() - 1]).is_err());
        // Corrupt the event tag.
        let mut bad = bytes.clone();
        bad[14] = 0xEE;
        assert!(read_journal(&bad).is_err());
    }

    #[test]
    fn tolerant_reader_stops_at_torn_tail_only() {
        let mut sink = JournalSink::new();
        let zero = ResultNotes::default();
        sink.record(0, 0.0, &EngineEvent::WorkerRequest { worker: 0 }, &[], &zero);
        let after_first = sink.bytes().len() as u64;
        sink.record(0, 1.0, &EngineEvent::WorkerRequest { worker: 1 }, &[], &zero);
        let bytes = sink.into_bytes();

        // Intact journal: everything decodes, valid prefix is the whole file.
        let (records, valid) = read_journal_tolerant(&bytes).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(valid, bytes.len() as u64);

        // Torn payload and torn length prefix: the strict reader errors, the
        // tolerant one yields the first record plus its byte boundary.
        for cut in [bytes.len() - 3, after_first as usize + 2] {
            assert!(read_journal(&bytes[..cut]).is_err());
            let (records, valid) = read_journal_tolerant(&bytes[..cut]).unwrap();
            assert_eq!(records.len(), 1);
            assert_eq!(valid, after_first);
        }

        // Mid-file corruption is NOT tolerated.
        let mut bad = bytes.clone();
        bad[14] = 0xEE;
        assert!(read_journal_tolerant(&bad).is_err());
        assert!(read_journal_tolerant(b"NOTAJRNL\x01\x00").is_err());
    }

    #[test]
    fn file_journal_survives_torn_tail_and_resume() {
        let path = std::env::temp_dir()
            .join(format!("rdlb-journal-test-{}.bin", std::process::id()));
        let zero = ResultNotes::default();

        // Write two records durably; the file must match the in-memory sink.
        let mut file_sink = FileJournal::create(&path).unwrap();
        let mut mem_sink = JournalSink::new();
        file_sink.record(0, 0.0, &EngineEvent::WorkerRequest { worker: 0 }, &[], &zero);
        mem_sink.record(0, 0.0, &EngineEvent::WorkerRequest { worker: 0 }, &[], &zero);
        file_sink.record(0, 1.0, &EngineEvent::WorkerRequest { worker: 1 }, &[], &zero);
        mem_sink.record(0, 1.0, &EngineEvent::WorkerRequest { worker: 1 }, &[], &zero);
        assert_eq!(file_sink.records(), 2);
        drop(file_sink);
        let on_disk = std::fs::read(&path).unwrap();
        assert_eq!(on_disk, mem_sink.bytes());

        // Tear the tail (as a crash mid-append would), then resume.
        std::fs::write(&path, &on_disk[..on_disk.len() - 3]).unwrap();
        let torn = std::fs::read(&path).unwrap();
        let (records, valid) = read_journal_tolerant(&torn).unwrap();
        assert_eq!(records.len(), 1);
        let mut resumed = FileJournal::append_after(&path, valid, records.len() as u64).unwrap();
        assert_eq!(resumed.records(), 1);
        resumed.record(0, 2.0, &EngineEvent::WorkerRequest { worker: 2 }, &[], &zero);
        assert_eq!(resumed.records(), 2);
        drop(resumed);

        // The healed journal is strictly valid again: record 1 survived the
        // tear, the torn record is gone, the new record follows it.
        let healed = read_journal(&std::fs::read(&path).unwrap()).unwrap();
        assert_eq!(healed.len(), 2);
        assert_eq!(healed[0].event, JournalEvent::Request { worker: 0 });
        assert_eq!(healed[1].event, JournalEvent::Request { worker: 2 });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_journal_is_valid_and_replays_to_default_stats() {
        let sink = JournalSink::new();
        let records = read_journal(sink.bytes()).unwrap();
        assert!(records.is_empty());
        assert_eq!(replay_stats(&records), MasterStats::default());
    }

    #[test]
    fn replay_counts_only_scope_zero() {
        let mut sink = JournalSink::new();
        let a = Effect::Assign(Assignment {
            id: 1,
            worker: 0,
            tasks: TaskSet::Range { start: 0, end: 4 },
            rescheduled: false,
        });
        let zero = ResultNotes::default();
        let one = std::slice::from_ref(&a);
        sink.record(0, 0.0, &EngineEvent::WorkerRequest { worker: 0 }, one, &zero);
        // An inner-group record must not leak into the root replay.
        sink.record(2, 0.0, &EngineEvent::WorkerRequest { worker: 0 }, one, &zero);
        let notes = ResultNotes {
            completed_chunks: 1,
            first_completions: 4,
            ..ResultNotes::default()
        };
        sink.record(
            0,
            0.5,
            &EngineEvent::ResultReceived {
                worker: 0,
                assignment_id: 1,
                compute_secs: 0.5,
                digests: &[],
            },
            &[Effect::Completed],
            &notes,
        );
        let s = replay_stats(&read_journal(sink.bytes()).unwrap());
        assert_eq!(s.requests, 1);
        assert_eq!(s.assigned_chunks, 1);
        assert_eq!(s.assigned_iterations, 4);
        assert_eq!(s.completed_chunks, 1);
        assert_eq!(s.finished_iterations, 4);
        assert_eq!(s.identity_violations(), Vec::<String>::new());
    }

    #[test]
    fn replay_folds_overdue_effects_into_health_counters() {
        let mut sink = JournalSink::new();
        let zero = ResultNotes::default();
        sink.record(
            0,
            1.0,
            &EngineEvent::HealthTick,
            &[
                Effect::Overdue { worker: 1, assignment_id: 3, quarantined: false },
                Effect::Overdue { worker: 2, assignment_id: 4, quarantined: true },
            ],
            &zero,
        );
        // An inner-group overdue must not leak into the root replay.
        sink.record(
            2,
            1.0,
            &EngineEvent::HealthTick,
            &[Effect::Overdue { worker: 0, assignment_id: 9, quarantined: true }],
            &zero,
        );
        sink.record(0, 1.1, &EngineEvent::Progress { worker: 1 }, &[], &zero);
        let s = replay_stats(&read_journal(sink.bytes()).unwrap());
        assert_eq!(s.overdue_chunks, 2);
        assert_eq!(s.quarantined_workers, 1);
        assert_eq!(s.requests, 0);
    }
}
