//! Building [`crate::trace::Trace`]s from the engine's event stream — the
//! one construction path shared by the live [`TraceSink`] (any runtime,
//! via the engine tap) and by [`replay_trace`] (offline, from a journal).
//!
//! Semantics: a chunk's record is opened by its `Assign` effect
//! (`assigned_at`), and closed by the matching result (`finished_at` = the
//! result's arrival time, `started_at` = arrival minus the reported
//! compute seconds).  A chunk whose result never arrives — evaporated by a
//! fail-stop, dropped by wire chaos, or outstanding when the run ends — is
//! marked `lost` when the trace is finalized.  This subsumes the
//! simulator's old inline `mark_lost` bookkeeping and extends traces to
//! the wall-clock runtimes, which have no mid-compute observability.
//!
//! Only scope-0 records are traced: for the hierarchical runtime that is
//! the root engine's super-chunk schedule (group-internal chunks remain
//! visible in the journal and the Chrome export).

use std::collections::HashMap;

use crate::coordinator::{Assignment, Effect, EngineEvent, EventSink, ResultNotes};
use crate::trace::{Trace, TraceRecord};

use super::journal::{JournalEvent, JournalRecord};

/// Incremental trace construction (see module docs for the semantics).
#[derive(Debug, Default)]
pub struct TraceBuilder {
    trace: Trace,
    /// `assignment_id` → index into `trace.records` for open chunks.
    open: HashMap<u64, usize>,
}

impl TraceBuilder {
    pub fn new() -> TraceBuilder {
        TraceBuilder::default()
    }

    /// A chunk was handed out at `now`.
    pub fn on_assign(&mut self, now: f64, a: &Assignment) {
        let idx = self.trace.len();
        self.trace.push(TraceRecord {
            assignment_id: a.id,
            worker: a.worker,
            first_task: a.tasks.first().unwrap_or(0),
            task_count: a.len(),
            assigned_at: now,
            started_at: None,
            finished_at: None,
            rescheduled: a.rescheduled,
            lost: false,
        });
        self.open.insert(a.id, idx);
    }

    /// The chunk's result arrived at `now` after `compute_secs` of work.
    pub fn on_result(&mut self, now: f64, assignment_id: u64, compute_secs: f64) {
        if let Some(idx) = self.open.remove(&assignment_id) {
            let r = &mut self.trace.records[idx];
            r.started_at = Some(now - compute_secs.max(0.0));
            r.finished_at = Some(now);
        }
    }

    /// Finalize: every still-open chunk evaporated (fail-stop, dropped
    /// frame, or run end) and is marked lost.
    pub fn finish(&mut self) -> Trace {
        for (_, idx) in self.open.drain() {
            self.trace.records[idx].lost = true;
        }
        std::mem::take(&mut self.trace)
    }
}

/// Live [`EventSink`] collecting a scope-0 [`Trace`] during any run.
#[derive(Debug, Default)]
pub struct TraceSink {
    builder: TraceBuilder,
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink::default()
    }

    /// Finalize and take the collected trace (call after the run).
    pub fn take_trace(&mut self) -> Trace {
        self.builder.finish()
    }
}

impl EventSink for TraceSink {
    fn record(
        &mut self,
        scope: u32,
        now: f64,
        event: &EngineEvent<'_>,
        effects: &[Effect],
        notes: &ResultNotes,
    ) {
        if scope != 0 {
            return;
        }
        if let EngineEvent::ResultReceived { assignment_id, compute_secs, .. } = event {
            if notes.unknown_results == 0 {
                self.builder.on_result(now, *assignment_id, *compute_secs);
            }
        }
        for eff in effects {
            if let Effect::Assign(a) = eff {
                self.builder.on_assign(now, a);
            }
        }
    }
}

/// Rebuild the scope-0 [`Trace`] from decoded journal records — identical
/// to what a live [`TraceSink`] would have collected during the run.
pub fn replay_trace(records: &[JournalRecord]) -> Trace {
    let mut b = TraceBuilder::new();
    for rec in records {
        if rec.scope != 0 {
            continue;
        }
        if let JournalEvent::Result { assignment_id, compute_secs, .. } = rec.event {
            if rec.notes.unknown_results == 0 {
                b.on_result(rec.now, assignment_id, compute_secs);
            }
        }
        for eff in &rec.effects {
            if let Effect::Assign(a) = eff {
                b.on_assign(rec.now, a);
            }
        }
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TaskSet;

    fn assign(id: u64, worker: usize, start: u32, end: u32, resched: bool) -> Assignment {
        Assignment { id, worker, tasks: TaskSet::Range { start, end }, rescheduled: resched }
    }

    #[test]
    fn builder_opens_closes_and_marks_lost() {
        let mut b = TraceBuilder::new();
        b.on_assign(0.0, &assign(1, 0, 0, 4, false));
        b.on_assign(0.1, &assign(2, 1, 4, 8, true));
        b.on_result(1.0, 1, 0.75);
        // Chunk 2 never reports; unknown ids are ignored.
        b.on_result(1.5, 99, 0.1);
        let t = b.finish();
        assert_eq!(t.len(), 2);
        let done = &t.records[0];
        assert_eq!(done.started_at, Some(0.25));
        assert_eq!(done.finished_at, Some(1.0));
        assert!(!done.lost);
        let lost = &t.records[1];
        assert!(lost.lost);
        assert!(lost.rescheduled);
        assert_eq!(lost.finished_at, None);
        assert_eq!(t.lost().count(), 1);
        assert_eq!(t.rescheduled().count(), 1);
    }

    #[test]
    fn trace_sink_ignores_inner_scopes_and_unknown_results() {
        let mut sink = TraceSink::new();
        let zero = ResultNotes::default();
        let a = Effect::Assign(assign(1, 0, 0, 2, false));
        sink.record(
            0,
            0.0,
            &EngineEvent::WorkerRequest { worker: 0 },
            std::slice::from_ref(&a),
            &zero,
        );
        // Inner-group assign must not appear in the scope-0 trace.
        let inner = Effect::Assign(assign(50, 0, 0, 2, false));
        sink.record(
            1,
            0.0,
            &EngineEvent::WorkerRequest { worker: 0 },
            std::slice::from_ref(&inner),
            &zero,
        );
        // An unknown-id result must not close anything.
        let unknown = ResultNotes { unknown_results: 1, ..ResultNotes::default() };
        sink.record(
            0,
            0.4,
            &EngineEvent::ResultReceived {
                worker: 0,
                assignment_id: 1,
                compute_secs: 0.1,
                digests: &[],
            },
            &[],
            &unknown,
        );
        let good =
            ResultNotes { completed_chunks: 1, first_completions: 2, ..ResultNotes::default() };
        sink.record(
            0,
            0.5,
            &EngineEvent::ResultReceived {
                worker: 0,
                assignment_id: 1,
                compute_secs: 0.1,
                digests: &[],
            },
            &[Effect::Completed],
            &good,
        );
        let t = sink.take_trace();
        assert_eq!(t.len(), 1);
        assert_eq!(t.records[0].finished_at, Some(0.5));
        assert_eq!(t.lost().count(), 0);
    }
}
