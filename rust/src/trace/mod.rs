//! Execution traces: per-chunk Gantt-style records, enough to regenerate the
//! paper's conceptual Figures 1 and 2 and to debug scheduling behaviour.


/// Lifecycle of one chunk assignment as observed by the simulator/runtime.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub assignment_id: u64,
    pub worker: usize,
    /// First task id and count (tasks of a chunk are ascending).
    pub first_task: u32,
    pub task_count: usize,
    /// Master clock when the chunk was assigned.
    pub assigned_at: f64,
    /// Worker clock when compute started (reply arrival); None if the reply
    /// never reached a live worker.
    pub started_at: Option<f64>,
    /// Worker clock when compute finished; None if lost to a failure.
    pub finished_at: Option<f64>,
    /// Issued by the rDLB re-dispatch phase?
    pub rescheduled: bool,
    /// Chunk evaporated due to a fail-stop failure.
    pub lost: bool,
}

/// A full execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    pub fn push(&mut self, r: TraceRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records of lost (failure-evaporated) chunks.
    pub fn lost(&self) -> impl Iterator<Item = &TraceRecord> {
        self.records.iter().filter(|r| r.lost)
    }

    /// Records issued by the rDLB phase.
    pub fn rescheduled(&self) -> impl Iterator<Item = &TraceRecord> {
        self.records.iter().filter(|r| r.rescheduled)
    }

    /// CSV dump (one row per record) — feed to any plotting tool for a
    /// Gantt chart like the paper's Figures 1–2.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "assignment_id,worker,first_task,task_count,assigned_at,started_at,finished_at,rescheduled,lost\n",
        );
        for r in &self.records {
            use std::fmt::Write;
            let _ = writeln!(
                s,
                "{},{},{},{},{:.9},{},{},{},{}",
                r.assignment_id,
                r.worker,
                r.first_task,
                r.task_count,
                r.assigned_at,
                r.started_at.map(|t| format!("{t:.9}")).unwrap_or_default(),
                r.finished_at.map(|t| format!("{t:.9}")).unwrap_or_default(),
                r.rescheduled,
                r.lost,
            );
        }
        s
    }

    /// Plain-text Gantt sketch (workers as rows, time buckets as columns) —
    /// handy in terminals; `width` is the number of time buckets.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let Some(end) = self
            .records
            .iter()
            .filter_map(|r| r.finished_at.or(r.started_at))
            .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.max(t))))
        else {
            return String::from("(empty trace)\n");
        };
        let p = self.records.iter().map(|r| r.worker).max().unwrap_or(0) + 1;
        let scale = end.max(1e-12) / width as f64;
        let mut rows = vec![vec![b'.'; width]; p];
        for r in &self.records {
            let (Some(s), Some(f)) = (r.started_at, r.finished_at) else { continue };
            let lo = ((s / scale) as usize).min(width - 1);
            let hi = ((f / scale) as usize).clamp(lo, width - 1);
            let ch = if r.rescheduled { b'R' } else { b'#' };
            for c in &mut rows[r.worker][lo..=hi] {
                *c = ch;
            }
        }
        let mut out = String::new();
        for (w, row) in rows.iter().enumerate() {
            out.push_str(&format!("P{w:<3} |"));
            out.push_str(std::str::from_utf8(row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!("     0 .. {end:.3}s  (#=primary R=rescheduled)\n"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, worker: usize, s: f64, f: f64, resched: bool) -> TraceRecord {
        TraceRecord {
            assignment_id: id,
            worker,
            first_task: 0,
            task_count: 1,
            assigned_at: s,
            started_at: Some(s),
            finished_at: Some(f),
            rescheduled: resched,
            lost: false,
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 0.5, 2.0, true));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("assignment_id,"));
    }

    #[test]
    fn filters() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 0.5, 2.0, true));
        assert_eq!(t.rescheduled().count(), 1);
        assert_eq!(t.lost().count(), 0);
    }

    #[test]
    fn gantt_renders() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 1.0, 2.0, true));
        let g = t.ascii_gantt(20);
        assert!(g.contains("P0"));
        assert!(g.contains('R'));
        assert!(g.contains('#'));
    }

    #[test]
    fn empty_gantt() {
        assert!(Trace::default().ascii_gantt(10).contains("empty"));
    }
}
