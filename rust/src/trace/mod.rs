//! Execution traces: per-chunk Gantt-style records, enough to regenerate the
//! paper's conceptual Figures 1 and 2 and to debug scheduling behaviour.


/// Lifecycle of one chunk assignment as observed by the simulator/runtime.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub assignment_id: u64,
    pub worker: usize,
    /// First task id and count (tasks of a chunk are ascending).
    pub first_task: u32,
    pub task_count: usize,
    /// Master clock when the chunk was assigned.
    pub assigned_at: f64,
    /// Worker clock when compute started (reply arrival); None if the reply
    /// never reached a live worker.
    pub started_at: Option<f64>,
    /// Worker clock when compute finished; None if lost to a failure.
    pub finished_at: Option<f64>,
    /// Issued by the rDLB re-dispatch phase?
    pub rescheduled: bool,
    /// Chunk evaporated due to a fail-stop failure.
    pub lost: bool,
}

/// A full execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    pub fn push(&mut self, r: TraceRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records of lost (failure-evaporated) chunks.
    pub fn lost(&self) -> impl Iterator<Item = &TraceRecord> {
        self.records.iter().filter(|r| r.lost)
    }

    /// Records issued by the rDLB phase.
    pub fn rescheduled(&self) -> impl Iterator<Item = &TraceRecord> {
        self.records.iter().filter(|r| r.rescheduled)
    }

    /// CSV dump (one row per record) — feed to any plotting tool for a
    /// Gantt chart like the paper's Figures 1–2.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "assignment_id,worker,first_task,task_count,assigned_at,started_at,finished_at,rescheduled,lost\n",
        );
        for r in &self.records {
            use std::fmt::Write;
            let _ = writeln!(
                s,
                "{},{},{},{},{:.9},{},{},{},{}",
                r.assignment_id,
                r.worker,
                r.first_task,
                r.task_count,
                r.assigned_at,
                r.started_at.map(|t| format!("{t:.9}")).unwrap_or_default(),
                r.finished_at.map(|t| format!("{t:.9}")).unwrap_or_default(),
                r.rescheduled,
                r.lost,
            );
        }
        s
    }

    /// Plain-text Gantt sketch (workers as rows, time buckets as columns) —
    /// handy in terminals; `width` is the number of time buckets.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let Some(end) = self
            .records
            .iter()
            .filter_map(|r| r.finished_at.or(r.started_at))
            .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.max(t))))
        else {
            return String::from("(empty trace)\n");
        };
        let p = self.records.iter().map(|r| r.worker).max().unwrap_or(0) + 1;
        let scale = end.max(1e-12) / width as f64;
        let mut rows = vec![vec![b'.'; width]; p];
        for r in &self.records {
            let (Some(s), Some(f)) = (r.started_at, r.finished_at) else { continue };
            let lo = ((s / scale) as usize).min(width - 1);
            let hi = ((f / scale) as usize).clamp(lo, width - 1);
            let ch = if r.rescheduled { b'R' } else { b'#' };
            for c in &mut rows[r.worker][lo..=hi] {
                *c = ch;
            }
        }
        let mut out = String::new();
        for (w, row) in rows.iter().enumerate() {
            out.push_str(&format!("P{w:<3} |"));
            out.push_str(std::str::from_utf8(row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!("     0 .. {end:.3}s  (#=primary R=rescheduled)\n"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, worker: usize, s: f64, f: f64, resched: bool) -> TraceRecord {
        TraceRecord {
            assignment_id: id,
            worker,
            first_task: 0,
            task_count: 1,
            assigned_at: s,
            started_at: Some(s),
            finished_at: Some(f),
            rescheduled: resched,
            lost: false,
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 0.5, 2.0, true));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("assignment_id,"));
    }

    #[test]
    fn filters() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 0.5, 2.0, true));
        assert_eq!(t.rescheduled().count(), 1);
        assert_eq!(t.lost().count(), 0);
    }

    #[test]
    fn gantt_renders() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 1.0, 2.0, true));
        let g = t.ascii_gantt(20);
        assert!(g.contains("P0"));
        assert!(g.contains('R'));
        assert!(g.contains('#'));
    }

    #[test]
    fn empty_gantt() {
        assert!(Trace::default().ascii_gantt(10).contains("empty"));
    }

    fn lost_rec(id: u64, worker: usize, assigned: f64) -> TraceRecord {
        TraceRecord {
            assignment_id: id,
            worker,
            first_task: 9,
            task_count: 3,
            assigned_at: assigned,
            started_at: None,
            finished_at: None,
            rescheduled: false,
            lost: true,
        }
    }

    #[test]
    fn csv_row_schema_matches_header_field_for_field() {
        let mut t = Trace::default();
        t.push(rec(7, 2, 0.25, 1.5, true));
        t.push(lost_rec(8, 1, 0.5));
        let csv = t.to_csv();
        let mut lines = csv.lines();
        let header: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(
            header,
            vec![
                "assignment_id",
                "worker",
                "first_task",
                "task_count",
                "assigned_at",
                "started_at",
                "finished_at",
                "rescheduled",
                "lost"
            ]
        );
        let row: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(row.len(), header.len(), "every row has exactly the header's arity");
        assert_eq!(row[0], "7");
        assert_eq!(row[1], "2");
        assert_eq!(row[7], "true");
        assert_eq!(row[8], "false");
        // A lost record keeps the arity, with empty start/finish cells.
        let lost: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(lost.len(), header.len());
        assert_eq!(lost[5], "", "unstarted chunk has an empty started_at cell");
        assert_eq!(lost[6], "", "lost chunk has an empty finished_at cell");
        assert_eq!(lost[8], "true");
        assert!(lines.next().is_none());
    }

    #[test]
    fn lost_and_rescheduled_filters_partition_correctly() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 0.5, 2.0, true));
        t.push(lost_rec(2, 2, 0.7));
        t.push(lost_rec(3, 0, 0.9));
        assert_eq!(t.lost().count(), 2);
        assert_eq!(t.rescheduled().count(), 1);
        assert!(t.lost().all(|r| r.finished_at.is_none()));
        assert_eq!(t.lost().map(|r| r.assignment_id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(t.len(), 4);
        assert!(!t.is_empty());
    }

    #[test]
    fn gantt_width_edge_cases() {
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 1.0, false));
        t.push(rec(1, 1, 0.9, 1.0, true));
        // width = 1: everything collapses into a single bucket per worker
        // without panicking on the lo/hi clamps.
        let g1 = t.ascii_gantt(1);
        for line in g1.lines().take(2) {
            let row = line.split('|').nth(1).unwrap();
            assert_eq!(row.len(), 1, "one bucket per worker at width=1: {line:?}");
        }
        // Large width: every row is exactly `width` buckets wide.
        let g = t.ascii_gantt(64);
        for line in g.lines().take(2) {
            let row = line.split('|').nth(1).unwrap();
            assert_eq!(row.len(), 64, "{line:?}");
        }
        // A chunk finishing exactly at the end lands in the last bucket.
        assert!(g.lines().nth(1).unwrap().trim_end().ends_with('R'));
    }

    #[test]
    fn gantt_with_only_unfinished_records_is_not_empty_banner() {
        // started_at set but finished_at lost: the time axis still exists
        // (the banner case is only for a trace with no timestamps at all).
        let mut t = Trace::default();
        t.push(TraceRecord { finished_at: None, ..rec(0, 0, 0.5, 1.0, false) });
        let g = t.ascii_gantt(8);
        assert!(!g.contains("empty"));
        assert!(g.contains("P0"));
        // Unfinished chunks draw nothing, so the row stays blank dots.
        assert!(g.lines().next().unwrap().contains("........"));
    }

    #[test]
    fn gantt_zero_duration_trace_renders() {
        // All timestamps identical: the scale guard (max with 1e-12) must
        // keep the bucket arithmetic finite.
        let mut t = Trace::default();
        t.push(rec(0, 0, 0.0, 0.0, false));
        let g = t.ascii_gantt(16);
        assert!(g.contains("P0"));
        assert!(g.contains('#'));
    }
}
