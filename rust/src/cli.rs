//! The `rdlb` command-line interface: subcommand parsing and drivers.
//!
//! Extracted from the 700-line `main.rs` so the flag → configuration
//! mapping is a unit-testable library surface (`main.rs` is now a thin
//! entry point calling [`execute`]).
//!
//! ```text
//! rdlb run        [--app A --technique T --pes P --tasks N --rdlb B --scenario S --seed K]
//!                 [--runtime sim|native|net|hier] [--groups G]
//!                 [--health] [--health-slack X --health-floor S --health-k K
//!                  --health-min-pool M --health-tick S]
//!                 [--journal FILE] [--metrics] [--trace-out FILE.csv] [--gantt WIDTH]
//! rdlb experiment --id fig3a|fig3b|fig3c|fig3d|fig4|fig5|table1 [--scale smoke|quick|paper] [--out DIR]
//! rdlb trace      [--scenario fig1|fig2] [--rdlb B]
//! rdlb trace-export --journal FILE [--csv FILE] [--gantt WIDTH] [--chrome FILE]
//! rdlb theory     [--reps R]
//! rdlb native     [--app A --workers W --technique T --rdlb B --backend native|pjrt
//!                  --artifacts DIR --failures F --tasks N]
//! rdlb serve      [--listen ADDR] [--workers P | --spawn-local P] [--app A --technique T]
//!                 [--rdlb | --no-rdlb] [--failures K --horizon S] [--tasks N --timeout S]
//!                 [--health ...] [--metrics-every SECS] [--journal-dir DIR | --resume DIR]
//! rdlb worker     --connect ADDR [--app A --backend native|pjrt --artifacts DIR]
//!                 [--reconnect S]
//! rdlb bench      [--scale smoke|quick|full] [--runtimes sim,native,net,hier] ...
//! rdlb chaos      [--seed K] [--budget quick|deep|N] [--hier] [--journal-oracle] [--master-kill]
//!                 [--stall] [--partition] ... | --replay FILE
//! ```

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::apps::AppKind;
use crate::bench::{
    compare_reports, run_campaign, BenchScale, BenchSettings, CampaignReport, Thresholds,
};
use crate::chaos::{self, ChaosBudget, ChaosSettings};
use crate::config::{ExperimentConfig, NetSettings, RuntimeKind, Scenario};
use crate::coordinator::{Engine, HealthPolicy, SharedSink};
use crate::dls::Technique;
use crate::experiments::{
    cells_to_csv, conceptual_trace, fig3_failures, fig3_perturbations, fig4_resilience,
    fig5_flexibility, perturb_to_csv, robustness_to_csv, run_outcome_observed, table1_summary,
    theory_validation, ConceptualScenario, Scale,
};
use crate::native::{ComputeBackend, NativeParams, NativeRuntime};
use crate::net::{
    bind_reusable, reconnect_backoff, run_worker, run_worker_reconnecting, serve_tcp,
    serve_tcp_session, wal, NetMasterParams, TcpTransport,
};
use crate::obs::{
    self, chrome_trace, read_journal, replay_stats, replay_trace, JournalSink, MetricsRegistry,
    MetricsSink, TraceSink,
};
use crate::runtime::ComputeService;
use crate::util::cli::Args;
use crate::util::signal;

const USAGE: &str = "\
rdlb — robust dynamic load balancing (Mohammed, Cavelan, Ciorba 2019) reproduction

USAGE:
  rdlb run        [--app mandelbrot|psia|uniform|exponential] [--technique SS|FAC|...]
                  [--pes P] [--tasks N] [--rdlb true|false]
                  [--scenario baseline|failures:<k>|pe|latency|combined|stall] [--seed K]
                  [--runtime sim|native|net|hier] [--groups G]
                  [--time-scale X] [--timeout S]
                  [--health] [--health-slack X] [--health-floor S] [--health-k K]
                  [--health-min-pool M] [--health-tick S]
                  [--journal FILE] [--metrics] [--trace-out FILE.csv] [--gantt WIDTH]
  rdlb experiment --id fig3a|fig3b|fig3c|fig3d|fig4|fig5|table1
                  [--scale smoke|quick|paper] [--out DIR]
  rdlb trace      [--scenario fig1|fig2] [--rdlb true|false]
  rdlb trace-export --journal FILE [--csv FILE] [--gantt WIDTH] [--chrome FILE]
  rdlb theory     [--reps R]
  rdlb native     [--app mandelbrot|psia] [--workers W] [--technique T]
                  [--rdlb true|false] [--backend native|pjrt]
                  [--artifacts DIR] [--failures F] [--tasks N] [--health ...]
  rdlb serve      [--config FILE] [--listen ADDR] [--workers P | --spawn-local P]
                  [--app mandelbrot|psia] [--technique T] [--rdlb | --no-rdlb]
                  [--failures K] [--horizon S] [--tasks N] [--timeout S]
                  [--health] [--health-slack X] [--health-floor S] [--health-k K]
                  [--health-min-pool M] [--health-tick S]
                  [--max-iter I] [--metrics-every SECS]
                  [--journal-dir DIR | --resume DIR]
  rdlb worker     [--config FILE] --connect ADDR [--app mandelbrot|psia]
                  [--backend native|pjrt] [--artifacts DIR] [--max-iter I]
                  [--retry-connect S] [--reconnect S]
  rdlb bench      [--scale smoke|quick|full] [--seed K] [--runtimes sim,native,net,hier]
                  [--jobs N] [--out FILE] [--compare BASELINE.json] [--threshold FRAC]
                  [--wall-threshold FRAC] [--events-threshold FRAC] [--quiet]
  rdlb chaos      [--seed K] [--budget quick|deep|N] [--jobs N] [--out-dir DIR]
                  [--shrink-budget N] [--hier] [--journal-oracle]
                  [--master-kill] [--stall] [--partition] [--quiet]
  rdlb chaos      --replay FILE

`run --runtime hier` executes the scenario on the two-level hierarchical
runtime: a root rDLB engine schedules coarse super-chunks across --groups G
group masters (default 2; G must divide P), and each group master runs a
full rDLB engine over its P/G workers. A group-master failure is tolerated
the same way a worker failure is — its super-chunk re-dispatches to a
surviving group. See ARCHITECTURE.md.

`bench` runs a seeded, deterministic benchmark campaign across the
runtimes × DLS techniques × fault scenarios — plus wire-codec microbench
cases (range vs list Assign frames, large Result frames) — and writes a
machine-readable BENCH_<n>.json (wall-time median/p95, task throughput,
simulator events/s, codec round-trips/s). With --compare it gates against a
committed baseline and exits non-zero on regressions beyond the thresholds
(default 0.25 = 25%), normalizing wall times by each report's stored CPU
calibration. `--jobs N` (default: every core) fans the simulator cases
across a bounded worker pool; wall-clock cases (native/net/hier — they
spawn their own worker threads and are gated on real time) are classified
Exclusive and always run serially after the parallel sim wave, so
oversubscription cannot skew their gated wall metrics. Outcome metrics and
report layout are identical at any job count. See README §Benchmarking
and §Performance, ARCHITECTURE.md §Parallel harness.

`chaos` fuzzes the whole system: a seeded generator draws random workloads
× DLS techniques × fault schedules (fail-stop up to P-1 workers incl.
mid-chunk, slowdown/latency, late joiners, stale-version churners, and
frame drop/duplicate/delay on the net runtime), runs every schedule on all
applicable runtimes (sim/native/net, plus hier with --hier) and checks an
invariant oracle: exactly-once completion (digest parity with the serial
kernel), cross-runtime digest agreement, completion despite <=P-1 failures
with rDLB on, documented hang-at-timeout with rDLB off, and the
MasterStats accounting identities. `--master-kill` additionally kills the
net master at a seeded point mid-run and resumes it by replaying its event
journal (the in-process twin of `serve --resume` after a kill -9); the
recovered run faces the same oracle. `--stall` arms a seeded mid-run worker
stall (hung with its connection open, heartbeating a frozen progress
counter — the SIGSTOP shape) and `--partition` a seeded both-direction
frame blackhole window; both also arm the worker-health layer, so overdue
detection and speculative re-dispatch race the injected straggler under
the same digest-parity oracle. Failing schedules are shrunk to a
minimal JSON reproducer (chaos_failure_<id>.json) that `--replay FILE`
re-executes deterministically. `--jobs N` (default: every core) executes
scenarios on a bounded worker pool; results fold in canonical scenario
order and shrinking stays single-threaded, so stdout and reproducers are
byte-identical at any job count (`--jobs 1` is the plain serial loop).
Output is seed-deterministic; exits non-zero on any violation. See
TESTING.md and ARCHITECTURE.md §Parallel harness.

`--health` (run/native/serve) arms the proactive worker-health layer: the
master keeps an online per-worker rate estimate, derives a per-chunk
deadline (predicted compute × --health-slack, floored at --health-floor
seconds), and flags overdue chunks for immediate speculative rDLB
re-dispatch instead of waiting for the hang bound — the straggler stays
registered, and its late result is still honored through the ordinary
first-completion filter. A worker going overdue --health-k times in a row
is quarantined (no new primary work; never below --health-min-pool
eligible workers) until it completes a chunk cleanly. On the net runtime
the v4 protocol adds Ping/Pong heartbeats carrying an in-chunk progress
counter, so a slow-but-alive worker is told apart from a gone one. Any
--health-* knob implies --health; all off by default, leaving seeded
outcomes bit-identical. See ARCHITECTURE.md §Worker health.

`serve` drives the distributed net runtime: it listens for P workers over
the length-prefixed TCP wire protocol and schedules with the identical rDLB
master the simulator uses. `--spawn-local P` forks P `rdlb worker`
processes against an ephemeral port for a one-command end-to-end run;
`--failures K` assigns fail-stop envelopes to K of the P workers (the
paper's §4 scenarios across real OS processes). `--metrics-every SECS`
prints a Prometheus-text metrics snapshot (engine events/s, latency
histograms) on that cadence.

With `--journal-dir DIR` the serve master write-ahead journals every
engine event into DIR (one fsync'd append per record). A master killed
mid-run — `kill -9` included — restarts with `rdlb serve --resume DIR`:
the journal (or snapshot + suffix) replays into the exact pre-crash
engine state, the dead session's in-flight chunks drop back to the pool,
and the run re-enters under a new epoch on the same listen address.
Workers run with `--reconnect S` ride out the crash and re-register;
results stamped with a pre-crash epoch are dropped, preserving
exactly-once digest parity. SIGINT/SIGTERM stop a journaled master
gracefully (snapshot written, workers left alive to reconnect). See
PROTOCOL.md appendix C and README §Crash recovery.

Observability (see ARCHITECTURE.md §Observability): every runtime drives
the same sans-I/O engine, so `run --journal FILE` records the complete
coordinator event stream of ANY runtime as a length-prefixed binary
journal (byte-identical across executions for a seeded sim run),
`--metrics` prints counter/histogram snapshots, and `--trace-out` /
`--gantt` derive the per-chunk trace live. `trace-export` converts a
journal offline into CSV, an ASCII Gantt chart, or Chrome trace_event
JSON (`--chrome`, loadable in about:tracing / ui.perfetto.dev), and
re-derives the MasterStats from the log — the differential oracle `chaos
--journal-oracle` checks against every live run.
";

/// Parse a `run` scenario word (`baseline`, `failures:<k>`, `pe`,
/// `latency`, `combined`, `stall`) against a `pes`-sized topology.
fn parse_scenario(s: &str, pes: usize) -> Result<Scenario> {
    let topo = if pes % 16 == 0 && pes >= 32 {
        crate::sim::Topology::new(pes / 16, 16)
    } else {
        crate::sim::Topology::flat(pes)
    };
    Ok(match s.trim().to_ascii_lowercase().as_str() {
        "baseline" => Scenario::Baseline,
        "pe" => Scenario::pe_perturb_default(&topo),
        "latency" => Scenario::latency_default(&topo),
        "combined" => Scenario::combined_default(&topo),
        "stall" => Scenario::stall_default(&topo),
        other => {
            if let Some(count) = other.strip_prefix("failures:") {
                Scenario::failures(count.parse()?)
            } else {
                bail!("unknown scenario {other}")
            }
        }
    })
}

/// Parse the worker-health flags shared by `run`, `native`, and `serve`:
/// `--health` arms the layer with its defaults, and any knob flag
/// (`--health-slack` &c.) both sets the knob and implies arming — nobody
/// tunes a disabled layer. With none of the flags present the returned
/// policy is the inert default, so seeded outcomes stay bit-identical.
fn health_from_args(args: &Args) -> Result<HealthPolicy> {
    const KNOBS: [&str; 5] =
        ["health-slack", "health-floor", "health-k", "health-min-pool", "health-tick"];
    let armed = args.bool_or("health", false)? || KNOBS.iter().any(|k| args.get(k).is_some());
    if !armed {
        return Ok(HealthPolicy::default());
    }
    let d = HealthPolicy::on();
    Ok(HealthPolicy {
        enabled: true,
        slack: args.f64_or("health-slack", d.slack)?,
        floor_secs: args.f64_or("health-floor", d.floor_secs)?,
        quarantine_k: args.u64_or("health-k", d.quarantine_k as u64)? as u32,
        min_pool: args.usize_or("health-min-pool", d.min_pool)?,
        tick_secs: args.f64_or("health-tick", d.tick_secs)?,
    })
}

/// Build the `rdlb run` experiment configuration from its flags — the pure
/// (unit-tested) half of [`cmd_run`].
fn run_config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let app = AppKind::parse(&args.str_or("app", "mandelbrot"))
        .ok_or_else(|| anyhow!("unknown app"))?;
    let technique = Technique::parse(&args.str_or("technique", "FAC"))
        .ok_or_else(|| anyhow!("unknown technique"))?;
    let runtime = RuntimeKind::parse(&args.str_or("runtime", "sim"))
        .ok_or_else(|| anyhow!("unknown runtime (sim|native|net|hier)"))?;
    // Real runtimes execute every virtual task as a wall-clock sleep with a
    // live thread per PE — default to a scale that stays tractable.
    let default_pes = if runtime == RuntimeKind::Sim { 256 } else { 8 };
    let pes = args.usize_or("pes", default_pes)?;
    let rdlb = args.bool_or("rdlb", true)?;
    let scenario = parse_scenario(&args.str_or("scenario", "baseline"), pes)?;
    let mut b = ExperimentConfig::builder()
        .app(app)
        .pes(pes)
        .technique(technique)
        .rdlb(rdlb)
        .runtime(runtime)
        .scenario(scenario)
        .seed(args.u64_or("seed", 1)?)
        .health(health_from_args(args)?);
    if let Some(groups) = args.usize_opt("groups")? {
        b = b.net(NetSettings { groups, ..NetSettings::default() });
    }
    if let Some(n) = args.usize_opt("tasks")? {
        b = b.tasks(n);
    } else if runtime != RuntimeKind::Sim {
        b = b.tasks(2048);
    }
    let mut cfg = b.build()?;
    cfg.net.timeout_secs = args.u64_or("timeout", cfg.net.timeout_secs)?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = run_config_from_args(args)?;
    let time_scale = args.f64_or("time-scale", 1.0)?;

    // Observability taps: each requested flag stacks one sink onto the
    // engine; with none requested no sink is installed and the run pays
    // only an untaken branch per event.
    let journal_path = args.get("journal").map(PathBuf::from);
    let metrics = args.bool_or("metrics", false)?;
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let gantt_width = args.usize_opt("gantt")?;

    let journal = journal_path.as_ref().map(|_| Arc::new(Mutex::new(JournalSink::new())));
    let registry = metrics.then(|| Arc::new(Mutex::new(MetricsRegistry::new())));
    let tracer = (trace_out.is_some() || gantt_width.is_some())
        .then(|| Arc::new(Mutex::new(TraceSink::new())));
    let mut sink: Option<SharedSink> = None;
    if let Some(j) = &journal {
        sink = Some(obs::with_extra_sink(sink.take(), SharedSink::from_arc(j.clone())));
    }
    if let Some(r) = &registry {
        sink = Some(obs::with_extra_sink(sink.take(), MetricsSink::new(r.clone())));
    }
    if let Some(t) = &tracer {
        sink = Some(obs::with_extra_sink(sink.take(), SharedSink::from_arc(t.clone())));
    }

    let t0 = std::time::Instant::now();
    let outcome = run_outcome_observed(&cfg, 0, time_scale, sink)?;
    print!(
        "app={} technique={} P={} N={} rdlb={} scenario={} runtime={}",
        cfg.app,
        cfg.technique,
        cfg.pes(),
        cfg.n(),
        cfg.rdlb,
        cfg.scenario.label(),
        cfg.runtime
    );
    if cfg.runtime == RuntimeKind::Hier {
        print!(" groups={}", cfg.net.groups);
    }
    println!();
    if outcome.hung {
        println!(
            "RESULT: HUNG (finished {}/{} — the paper's 'waits indefinitely' case)",
            outcome.finished, outcome.n
        );
    } else {
        println!("RESULT: T_par = {:.4}s", outcome.parallel_time);
    }
    println!(
        "chunks={} rescheduled={} duplicates={} waste={:.2}%  (wall {:?})",
        outcome.stats.assigned_chunks,
        outcome.stats.rescheduled_chunks,
        outcome.stats.duplicate_iterations,
        outcome.waste_fraction() * 100.0,
        t0.elapsed()
    );

    if let (Some(path), Some(j)) = (&journal_path, &journal) {
        let bytes = j.lock().unwrap_or_else(|e| e.into_inner()).bytes().to_vec();
        std::fs::write(path, &bytes)
            .with_context(|| format!("write journal {}", path.display()))?;
        println!("journal: wrote {} ({} bytes)", path.display(), bytes.len());
    }
    if let Some(r) = &registry {
        print!("{}", r.lock().unwrap_or_else(|e| e.into_inner()).to_prometheus());
    }
    if let Some(t) = &tracer {
        let trace = t.lock().unwrap_or_else(|e| e.into_inner()).take_trace();
        if let Some(path) = &trace_out {
            std::fs::write(path, trace.to_csv())
                .with_context(|| format!("write trace {}", path.display()))?;
            println!(
                "trace: wrote {} ({} chunks, {} lost)",
                path.display(),
                trace.len(),
                trace.lost().count()
            );
        }
        if let Some(w) = gantt_width {
            println!("{}", trace.ascii_gantt(w.max(20)));
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.get("id").ok_or_else(|| anyhow!("--id required"))?.to_string();
    let scale = Scale::parse(&args.str_or("scale", "quick"))
        .ok_or_else(|| anyhow!("unknown scale (smoke|quick|paper)"))?;
    let out = PathBuf::from(args.str_or("out", "results"));
    std::fs::create_dir_all(&out)?;
    let write = |name: &str, data: &str| -> Result<()> {
        let path = out.join(name);
        std::fs::write(&path, data)?;
        println!("wrote {}", path.display());
        Ok(())
    };
    match id.as_str() {
        "fig3a" | "fig3b" => {
            let app = if id == "fig3a" { AppKind::Psia } else { AppKind::Mandelbrot };
            let data = fig3_failures(app, &scale)?;
            write(&format!("{id}.csv"), &cells_to_csv(&data.cells))?;
        }
        "fig3c" | "fig3d" => {
            let app = if id == "fig3c" { AppKind::Psia } else { AppKind::Mandelbrot };
            let cells = fig3_perturbations(app, &scale)?;
            write(&format!("{id}.csv"), &perturb_to_csv(&cells))?;
        }
        "fig4" => {
            for (app, tag) in [(AppKind::Psia, "psia"), (AppKind::Mandelbrot, "mandelbrot")] {
                let fig3 = fig3_failures(app, &scale)?;
                let tables = fig4_resilience(&fig3);
                write(&format!("fig4_{tag}.csv"), &robustness_to_csv(&tables))?;
            }
        }
        "fig5" => {
            for (app, tag) in [(AppKind::Psia, "psia"), (AppKind::Mandelbrot, "mandelbrot")] {
                let cells = fig3_perturbations(app, &scale)?;
                let tables: Vec<_> =
                    fig5_flexibility(&cells).into_iter().flat_map(|(a, b)| [a, b]).collect();
                write(&format!("fig5_{tag}.csv"), &robustness_to_csv(&tables))?;
            }
        }
        "table1" => {
            let data = table1_summary(&scale)?;
            write("table1.csv", &cells_to_csv(&data.cells))?;
        }
        other => bail!("unknown experiment id {other} (fig3a|fig3b|fig3c|fig3d|fig4|fig5|table1)"),
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let rdlb = args.bool_or("rdlb", true)?;
    let sc = match args.str_or("scenario", "fig1").as_str() {
        "fig1" => ConceptualScenario::Failure { rdlb },
        "fig2" => ConceptualScenario::Perturbation { rdlb },
        other => bail!("unknown trace scenario {other}"),
    };
    let (outcome, trace) = conceptual_trace(sc)?;
    println!("{}", trace.ascii_gantt(72));
    if outcome.hung {
        println!("outcome: HUNG after {}/{} tasks", outcome.finished, outcome.n);
    } else {
        println!("outcome: completed in {:.3}s", outcome.parallel_time);
    }
    Ok(())
}

/// `rdlb trace-export`: convert a binary engine journal (written by
/// `rdlb run --journal FILE`) into human- and tool-facing formats.
fn cmd_trace_export(args: &Args) -> Result<()> {
    let path = args
        .get("journal")
        .ok_or_else(|| anyhow!("trace-export: --journal FILE is required"))?
        .to_string();
    let bytes = std::fs::read(&path).with_context(|| format!("reading journal {path}"))?;
    let records = read_journal(&bytes)?;
    let stats = replay_stats(&records);
    println!(
        "journal: {} records ({} bytes); replayed stats: {} requests, \
         {}/{} chunks completed/assigned, {} rescheduled chunks, \
         {} finished iterations, {} duplicates",
        records.len(),
        bytes.len(),
        stats.requests,
        stats.completed_chunks,
        stats.assigned_chunks,
        stats.rescheduled_chunks,
        stats.finished_iterations,
        stats.duplicate_iterations,
    );

    let csv_out = args.get("csv").map(str::to_string);
    let gantt_width = args.usize_opt("gantt")?;
    let chrome_out = args.get("chrome").map(str::to_string);
    if csv_out.is_none() && gantt_width.is_none() && chrome_out.is_none() {
        println!("trace-export: nothing exported; pass --csv FILE, --gantt WIDTH, --chrome FILE");
        return Ok(());
    }

    if csv_out.is_some() || gantt_width.is_some() {
        let trace = replay_trace(&records);
        if let Some(file) = &csv_out {
            std::fs::write(file, trace.to_csv()).with_context(|| format!("writing {file}"))?;
            println!(
                "trace: wrote {file} ({} chunks, {} lost, {} rescheduled)",
                trace.len(),
                trace.lost().count(),
                trace.rescheduled().count()
            );
        }
        if let Some(w) = gantt_width {
            println!("{}", trace.ascii_gantt(w.max(20)));
        }
    }
    if let Some(file) = &chrome_out {
        let json = chrome_trace(&records);
        std::fs::write(file, json.to_string_pretty()).with_context(|| format!("writing {file}"))?;
        println!("chrome: wrote {file} (load in about:tracing or ui.perfetto.dev)");
    }
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let reps = args.usize_or("reps", 16)?;
    println!("§3.1 theory vs simulation (one certain failure, equal tasks):");
    println!("{:>6} {:>12} {:>12} {:>8}", "q", "T_model", "T_sim", "rel_err");
    for (q, model, sim, err) in theory_validation(reps)? {
        println!("{q:>6} {model:>12.5} {sim:>12.5} {err:>8.4}");
    }
    let p =
        crate::analysis::TheoryParams { n_per_pe: 1024.0, q: 256.0, t_task: 2e-3, lambda: 1e-5 };
    println!(
        "\noverhead (λ=1e-5, q=256): rDLB {:.3e}, checkpoint crossover C* = {:.3e}s",
        p.overhead_rdlb(),
        p.checkpoint_crossover()
    );
    Ok(())
}

/// CLI kernel shapes — the single source of truth for per-app task
/// capacity, shared by `build_backend` (worker side) and `cmd_serve`'s
/// `--tasks` bound (master side).
const MANDELBROT_GRID: (usize, usize) = (256, 256);
const PSIA_CLI_TASKS: usize = 4096;

/// Per-app task capacity of the CLI kernels.
fn kernel_capacity(app: AppKind) -> Result<usize> {
    Ok(match app {
        AppKind::Mandelbrot => MANDELBROT_GRID.0 * MANDELBROT_GRID.1,
        AppKind::Psia => PSIA_CLI_TASKS,
        other => bail!("the native/net CLI kernels support mandelbrot|psia (got {other})"),
    })
}

/// Build the compute backend for `app`/`backend_kind`, returning the
/// kernel's task capacity alongside it. A spawned PJRT service (if any) is
/// parked in `keepalive` so it outlives the run.
fn build_backend(
    app: AppKind,
    backend_kind: &str,
    artifacts: &Path,
    max_iter: u32,
    keepalive: &mut Option<ComputeService>,
) -> Result<(usize, ComputeBackend)> {
    let capacity = kernel_capacity(app)?;
    Ok(match (app, backend_kind) {
        (AppKind::Mandelbrot, "native") => {
            let a = crate::apps::MandelbrotApp {
                width: MANDELBROT_GRID.0,
                height: MANDELBROT_GRID.1,
                max_iter,
                ..Default::default()
            };
            debug_assert_eq!(a.n_tasks(), capacity);
            (capacity, ComputeBackend::Mandelbrot(std::sync::Arc::new(a)))
        }
        (AppKind::Psia, "native") => {
            let a = crate::apps::PsiaApp::synthetic(PSIA_CLI_TASKS);
            debug_assert_eq!(a.n_tasks(), capacity);
            (capacity, ComputeBackend::Psia(std::sync::Arc::new(a)))
        }
        (AppKind::Mandelbrot | AppKind::Psia, "pjrt") => {
            let svc = ComputeService::spawn(artifacts.to_path_buf())?;
            let handle = svc.handle();
            *keepalive = Some(svc);
            let backend = if app == AppKind::Mandelbrot {
                ComputeBackend::PjrtMandelbrot(handle)
            } else {
                ComputeBackend::PjrtPsia(handle)
            };
            (capacity, backend)
        }
        (a, b) => bail!("unsupported app/backend combo {a}/{b}"),
    })
}

fn cmd_native(args: &Args) -> Result<()> {
    let app =
        AppKind::parse(&args.str_or("app", "mandelbrot")).ok_or_else(|| anyhow!("unknown app"))?;
    let technique = Technique::parse(&args.str_or("technique", "FAC"))
        .ok_or_else(|| anyhow!("unknown technique"))?;
    let workers = args.usize_or("workers", 8)?;
    let rdlb = args.bool_or("rdlb", true)?;
    let backend_kind = args.str_or("backend", "native");
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let failures = args.usize_or("failures", 0)?;
    let max_iter = args.u64_or("max-iter", 300)? as u32;

    // The service must outlive the run when the PJRT backend is used.
    let mut _service_keepalive: Option<ComputeService> = None;
    let (n_default, backend) =
        build_backend(app, &backend_kind, &artifacts, max_iter, &mut _service_keepalive)?;
    let n = args.usize_opt("tasks")?.unwrap_or(n_default);
    let mut params = NativeParams::new(n, workers, technique, rdlb, backend);
    if failures > 0 {
        // Same bound the net runtime enforces; the library-level
        // `with_failures` would otherwise silently saturate at P-1.
        anyhow::ensure!(
            failures < workers,
            "at most P-1 failures are tolerable (got {failures} for P={workers})"
        );
        params = params.with_failures(failures, 2.0);
    }
    params.timeout = std::time::Duration::from_secs(args.u64_or("timeout", 120)?);
    params.health = health_from_args(args)?;
    let t0 = std::time::Instant::now();
    let outcome = NativeRuntime::new(params)?.run()?;
    if outcome.hung {
        println!("RESULT: HUNG (finished {}/{})", outcome.finished, outcome.n);
    } else {
        println!(
            "RESULT: T_par = {:.3}s  chunks={} rescheduled={} duplicates={}  (wall {:?})",
            outcome.parallel_time,
            outcome.stats.assigned_chunks,
            outcome.stats.rescheduled_chunks,
            outcome.stats.duplicate_iterations,
            t0.elapsed()
        );
    }
    Ok(())
}

/// Load `--config FILE` (an [`ExperimentConfig`] JSON, including its `net`
/// settings) when given; CLI flags override its values.
fn load_config(args: &Args) -> Result<Option<ExperimentConfig>> {
    match args.get("config") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("read config {path}"))?;
            Ok(Some(ExperimentConfig::from_json(&text)?))
        }
        None => Ok(None),
    }
}

/// `rdlb serve`: the distributed master. Binds the listener, optionally
/// forks `--spawn-local P` worker processes against it, accepts P
/// registrations and drives the run over the wire protocol. Defaults come
/// from `--config FILE` (its `net` block supplies listen / spawn_local /
/// timeout) with flags taking precedence.
fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(dir) = args.get("resume") {
        anyhow::ensure!(
            args.get("journal-dir").is_none(),
            "--journal-dir and --resume are mutually exclusive \
             (--resume keeps journaling into its own directory)"
        );
        let dir = PathBuf::from(dir);
        return cmd_serve_resume(args, &dir);
    }
    let file = load_config(args)?;
    let net = file.as_ref().map(|c| c.net.clone()).unwrap_or_default();
    let app = match args.get("app") {
        Some(s) => AppKind::parse(s).ok_or_else(|| anyhow!("unknown app"))?,
        None => file.as_ref().map(|c| c.app).unwrap_or(AppKind::Mandelbrot),
    };
    let technique = match args.get("technique") {
        Some(s) => Technique::parse(s).ok_or_else(|| anyhow!("unknown technique"))?,
        None => file.as_ref().map(|c| c.technique).unwrap_or(Technique::Fac),
    };
    // Flags override the config: an explicit --spawn-local wins outright,
    // and an explicit --workers suppresses the config's spawn_local.
    let spawn_flag = args.usize_opt("spawn-local")?;
    let workers_flag = args.usize_opt("workers")?;
    let spawn_local = match (spawn_flag, workers_flag) {
        (Some(p), _) => Some(p),
        (None, Some(_)) => None,
        (None, None) => net.spawn_local,
    };
    // Worker count falls back to the config's topology (P = nodes × ranks).
    let workers = spawn_local
        .or(workers_flag)
        .or_else(|| file.as_ref().map(|c| c.pes()))
        .unwrap_or(4);
    anyhow::ensure!(workers >= 1, "need at least one worker");
    let rdlb_default = file.as_ref().map(|c| c.rdlb).unwrap_or(true);
    let rdlb =
        if args.bool_or("no-rdlb", false)? { false } else { args.bool_or("rdlb", rdlb_default)? };
    // Failure count falls back to the config's scenario; serve has no
    // perturbation surface (use `run --runtime net` for those), so a
    // perturbation scenario in the config is refused rather than silently
    // run as baseline.
    let cfg_failures = match file.as_ref().map(|c| c.scenario) {
        None | Some(Scenario::Baseline) => 0,
        Some(Scenario::Failures { count }) => count,
        Some(other) => bail!(
            "serve does not support the {} scenario from --config; \
             use `rdlb run --runtime net` for perturbations",
            other.label()
        ),
    };
    let failures = args.usize_or("failures", cfg_failures)?;
    let horizon = args.f64_or("horizon", 1.0)?;
    let timeout = Duration::from_secs(args.u64_or("timeout", net.timeout_secs)?);
    // Forwarded to --spawn-local workers. The default is deliberately heavy
    // (vs `native`'s 300) so the run outlasts the failure horizon and the
    // injected fail-stops actually fire mid-run on any machine.
    let max_iter = args.u64_or("max-iter", 50_000)?;
    // Listen precedence: flag, then a loaded config's address, then an
    // ephemeral port for flag-driven --spawn-local runs.
    let listen = match (args.get("listen"), file.is_some()) {
        (Some(l), _) => l.to_string(),
        (None, true) => net.listen.clone(),
        (None, false) if spawn_local.is_some() => "127.0.0.1:0".to_string(),
        (None, false) => net.listen.clone(),
    };

    // N defaults to the worker-side kernel's capacity; workers rebuild the
    // same kernel from `--app`, so N may not exceed it.
    let n_default = kernel_capacity(app)?;
    let n = args
        .usize_opt("tasks")?
        .or(file.as_ref().and_then(|c| c.tasks))
        .unwrap_or(n_default);
    anyhow::ensure!(
        (1..=n_default).contains(&n),
        "--tasks must be in 1..={n_default} for {app} (workers size their kernel to it)"
    );

    // --journal-dir DIR: arm the write-ahead state directory so this run
    // can be killed and resumed (see `net::wal`).
    let wal_dir = args.get("journal-dir").map(PathBuf::from);

    let listener =
        TcpListener::bind(&listen).with_context(|| format!("bind listener on {listen}"))?;
    let addr = listener.local_addr()?;
    println!(
        "serve: listening on {addr} for {workers} workers \
         (app={app}, technique={technique}, N={n}, rdlb={rdlb}, failures={failures})"
    );

    // Health precedence mirrors every other serve flag: explicit --health*
    // flags win, then a loaded config's policy, else disabled.
    let mut health = health_from_args(args)?;
    if !health.enabled {
        if let Some(c) = &file {
            health = c.health.clone();
        }
    }
    let mut params = NetMasterParams::new(n, workers, technique, rdlb);
    params.timeout = timeout;
    params.health = health.clone();
    if health.enabled {
        println!(
            "serve: worker-health armed (deadline = prediction x {} slack, floor {}s, \
             tick {}s, quarantine after {} consecutive overdue)",
            health.slack, health.floor_secs, health.tick_secs, health.quarantine_k
        );
    }
    if failures > 0 {
        params = params.with_failures(failures, horizon)?;
        for (w, fault) in params.faults.iter().enumerate() {
            if let Some(t) = fault.fail_after {
                println!("serve: worker {w} will fail-stop {t:.2}s after registration");
            }
        }
    }

    arm_metrics(args, &mut params)?;

    if let Some(dir) = &wal_dir {
        let meta = wal::WalMeta {
            app,
            technique,
            n,
            workers,
            rdlb,
            max_iter,
            timeout_secs: timeout.as_secs(),
            listen: addr.to_string(),
            epoch: 0,
            health: health.clone(),
        };
        let journal = wal::create(dir, &meta)?;
        params.sink = Some(obs::with_extra_sink(params.sink.take(), journal));
        println!(
            "serve: write-ahead journal at {} (after a crash: rdlb serve --resume {})",
            dir.display(),
            dir.display()
        );
        let engine = Engine::new(meta.master_config());
        let mut children = match spawn_local {
            // Journaled children get a reconnect window: they must ride out
            // a master kill and re-Hello into the resumed session.
            Some(_) => spawn_local_workers(&addr.to_string(), app, max_iter, workers, Some(60))?,
            None => Vec::new(),
        };
        let shutdown = signal::install_shutdown_handler();
        let t0 = Instant::now();
        let result = serve_tcp_session(
            listener,
            params,
            timeout.max(Duration::from_secs(30)),
            engine,
            Some(shutdown),
            false,
        );
        for child in &mut children {
            let _ = child.kill();
            let _ = child.wait();
        }
        let (outcome, engine) = result?;
        let covered = wal::snapshot_now(dir, &engine)?;
        if signal::shutdown_requested() && !engine.is_complete() {
            println!(
                "serve: shutdown — {covered} journal records + snapshot saved to {}; \
                 finish with `rdlb serve --resume {}`",
                dir.display(),
                dir.display()
            );
            return Ok(());
        }
        print_serve_result(&outcome, timeout, t0);
        return Ok(());
    }

    let mut children = match spawn_local {
        Some(_) => spawn_local_workers(&addr.to_string(), app, max_iter, workers, None)?,
        None => Vec::new(),
    };

    let t0 = Instant::now();
    let result = serve_tcp(listener, params, timeout.max(Duration::from_secs(30)));
    // Reap the forked workers regardless of how the run ended; Terminate
    // has already been sent, the kill only catches wedged stragglers.
    for child in &mut children {
        let _ = child.kill();
        let _ = child.wait();
    }
    let outcome = result?;
    print_serve_result(&outcome, timeout, t0);
    Ok(())
}

/// `rdlb serve --resume DIR`: recover a crashed (or signal-stopped)
/// journaled run.  The state directory is authoritative for every run
/// parameter (only `--timeout`, `--metrics-every` and `--spawn-local` are
/// honoured as flags), and the original listen address is re-bound with
/// `SO_REUSEADDR` so surviving workers reconnect to the address they
/// already know.
fn cmd_serve_resume(args: &Args, dir: &Path) -> Result<()> {
    let r = wal::resume(dir)?;
    let meta = r.meta;
    println!(
        "serve: resumed epoch {} from {} — {} journal records recovered, \
         {}/{} tasks already finished, {} in-flight chunks dropped for re-dispatch",
        meta.epoch,
        dir.display(),
        r.replayed_records,
        r.engine.finished_count(),
        meta.n,
        r.dropped_in_flight
    );
    if r.engine.is_complete() {
        // The crash landed between the final journaled result and exit.
        println!(
            "RESULT: T_par = recovered-complete  finished={}/{} digest={:.1}",
            r.engine.finished_count(),
            meta.n,
            r.engine.result_digest()
        );
        return Ok(());
    }
    let timeout = Duration::from_secs(args.u64_or("timeout", meta.timeout_secs)?);
    let listener = bind_reusable(&meta.listen)?;
    let addr = listener.local_addr()?;
    println!(
        "serve: listening on {addr} for up to {} reconnecting workers \
         (app={}, technique={}, N={}, rdlb={}, epoch={})",
        meta.workers, meta.app, meta.technique, meta.n, meta.rdlb, meta.epoch
    );
    let mut params = NetMasterParams::new(meta.n, meta.workers, meta.technique, meta.rdlb);
    params.timeout = timeout;
    // The state directory is authoritative: the resumed session re-arms the
    // crashed run's health policy (the recovered snapshot carries matching
    // per-worker deadline state).
    params.health = meta.health.clone();
    params.sink = Some(SharedSink::new(r.journal));
    arm_metrics(args, &mut params)?;

    let mut children = Vec::new();
    if let Some(p) = args.usize_opt("spawn-local")? {
        anyhow::ensure!(
            p == meta.workers,
            "--spawn-local {p} does not match the run's {} workers",
            meta.workers
        );
        children = spawn_local_workers(&addr.to_string(), meta.app, meta.max_iter, p, Some(60))?;
    }
    let shutdown = signal::install_shutdown_handler();
    let t0 = Instant::now();
    let result = serve_tcp_session(
        listener,
        params,
        timeout.max(Duration::from_secs(30)),
        r.engine,
        Some(shutdown),
        // A fail-stopped worker never reconnects: proceed with whoever
        // re-registered and let rDLB re-dispatch cover the rest.
        true,
    );
    for child in &mut children {
        let _ = child.kill();
        let _ = child.wait();
    }
    let (outcome, engine) = result?;
    let covered = wal::snapshot_now(dir, &engine)?;
    if signal::shutdown_requested() && !engine.is_complete() {
        println!(
            "serve: shutdown — {covered} journal records + snapshot saved; \
             finish with `rdlb serve --resume {}`",
            dir.display()
        );
        return Ok(());
    }
    print_serve_result(&outcome, timeout, t0);
    Ok(())
}

/// `--metrics-every SECS`: tap the engine with a MetricsSink (composed
/// with any sink already installed — e.g. the WAL journal) and print a
/// Prometheus snapshot (plus a frames/s rate derived by diffing
/// rdlb_events_total between snapshots) on that cadence.  The printer
/// thread is spawn-and-forget: it dies with the process once the run's
/// RESULT line is out.
fn arm_metrics(args: &Args, params: &mut NetMasterParams) -> Result<()> {
    let metrics_every = args.u64_or("metrics-every", 0)?;
    if metrics_every == 0 {
        return Ok(());
    }
    let registry = Arc::new(Mutex::new(MetricsRegistry::new()));
    params.sink =
        Some(obs::with_extra_sink(params.sink.take(), MetricsSink::new(registry.clone())));
    let reg = Arc::clone(&registry);
    let every = Duration::from_secs(metrics_every);
    std::thread::spawn(move || {
        let mut last_events = 0u64;
        loop {
            std::thread::sleep(every);
            let snapshot = reg.lock().unwrap_or_else(|e| e.into_inner()).clone();
            let events = snapshot.counter("rdlb_events_total");
            println!(
                "metrics: {:.1} engine events/s over the last {}s",
                (events.saturating_sub(last_events)) as f64 / every.as_secs_f64(),
                every.as_secs()
            );
            print!("{}", snapshot.to_prometheus());
            last_events = events;
        }
    });
    Ok(())
}

/// Fork `rdlb worker` processes against `addr` for `--spawn-local`.
/// `reconnect_secs` is forwarded as `--reconnect` when the master journals:
/// such workers must survive a master kill and re-Hello into the resumed
/// session instead of exiting on the lost connection.
fn spawn_local_workers(
    addr: &str,
    app: AppKind,
    max_iter: u64,
    workers: usize,
    reconnect_secs: Option<u64>,
) -> Result<Vec<std::process::Child>> {
    let exe = std::env::current_exe().context("resolve current executable")?;
    let mut children = Vec::with_capacity(workers);
    for i in 0..workers {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .arg("--connect")
            .arg(addr)
            .arg("--app")
            .arg(app.name().to_ascii_lowercase())
            .arg("--max-iter")
            .arg(max_iter.to_string())
            .arg("--retry-connect")
            .arg("10");
        if let Some(s) = reconnect_secs {
            cmd.arg("--reconnect").arg(s.to_string());
        }
        let child = cmd.spawn().with_context(|| format!("spawn local worker {i}"))?;
        children.push(child);
    }
    println!("serve: spawned {workers} local worker processes");
    Ok(children)
}

/// The serve RESULT line, shared by fresh and resumed runs.
fn print_serve_result(outcome: &crate::sim::Outcome, timeout: Duration, t0: Instant) {
    if outcome.hung {
        println!(
            "RESULT: HUNG at the {}s hang bound (finished {}/{} — the paper's \
             'waits indefinitely' case)",
            timeout.as_secs(),
            outcome.finished,
            outcome.n
        );
    } else {
        println!(
            "RESULT: T_par = {:.3}s  chunks={} rescheduled={} duplicates={} digest={:.1}  (wall {:?})",
            outcome.parallel_time,
            outcome.stats.assigned_chunks,
            outcome.stats.rescheduled_chunks,
            outcome.stats.duplicate_iterations,
            outcome.result_digest,
            t0.elapsed()
        );
    }
}

/// `rdlb worker`: connect to a serving master and compute until terminated.
fn cmd_worker(args: &Args) -> Result<()> {
    let file = load_config(args)?;
    let app = match args.get("app") {
        Some(s) => AppKind::parse(s).ok_or_else(|| anyhow!("unknown app"))?,
        None => file.as_ref().map(|c| c.app).unwrap_or(AppKind::Mandelbrot),
    };
    let backend_kind = args.str_or("backend", "native");
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let connect = match args.get("connect") {
        Some(c) => c.to_string(),
        None => file.map(|c| c.net.connect).unwrap_or_else(|| NetSettings::default().connect),
    };
    let max_iter = args.u64_or("max-iter", 300)? as u32;
    // Retry window for connection errors. 0 (the default) surfaces a wrong
    // address immediately; `serve --spawn-local` passes 10 s to its forked
    // workers to cover the master's accept loop coming up a beat late.
    let retry_secs = args.f64_or("retry-connect", 0.0)?.max(0.0);
    let retry = Duration::from_secs_f64(retry_secs);
    // --reconnect S: survive a master crash.  On a lost connection, keep
    // re-dialing for S seconds and re-Hello into the resumed session (a
    // journaled `serve --spawn-local` hands its workers this flag).
    let reconnect_secs = args.f64_or("reconnect", 0.0)?.max(0.0);

    let mut _service_keepalive: Option<ComputeService> = None;
    let (_capacity, backend) =
        build_backend(app, &backend_kind, &artifacts, max_iter, &mut _service_keepalive)?;
    let label = format!("{}/{}", app.name().to_ascii_lowercase(), backend_kind);

    if reconnect_secs > 0.0 {
        // The window also covers the initial connect, so it subsumes
        // --retry-connect.
        let window = Duration::from_secs_f64(reconnect_secs.max(retry_secs));
        let report = run_worker_reconnecting(&connect, backend, &label, window)?;
        println!(
            "worker {}: {} chunks, {} iterations{}",
            report.worker,
            report.chunks,
            report.iterations,
            if report.failed { " (fail-stop injected)" } else { "" }
        );
        return Ok(());
    }

    // Address-seeded exponential backoff instead of a fixed 50 ms spin, so
    // a fleet of workers aimed at a not-yet-listening master desynchronizes
    // instead of thundering at it in lockstep (run_worker_reconnecting uses
    // the same schedule for its crash-recovery redials).
    let deadline = Instant::now() + retry;
    let mut backoff = reconnect_backoff(&connect);
    let transport = loop {
        match TcpTransport::connect(&connect) {
            Ok(t) => break t,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff.next_delay());
            }
        }
    };

    let report = run_worker(Box::new(transport), backend, &label)?;
    println!(
        "worker {}: {} chunks, {} iterations{}",
        report.worker,
        report.chunks,
        report.iterations,
        if report.failed { " (fail-stop injected)" } else { "" }
    );
    Ok(())
}

/// Find the first unused `BENCH_<n>.json` name in the current directory.
fn next_bench_path() -> PathBuf {
    for k in 1..10_000u32 {
        let candidate = PathBuf::from(format!("BENCH_{k}.json"));
        if !candidate.exists() {
            return candidate;
        }
    }
    PathBuf::from("BENCH_overflow.json")
}

/// Resolve `--jobs N` for the parallel campaign harnesses: defaults to
/// every available core, rejects zero (a pool with no workers cannot make
/// progress).  `--jobs 1` is the plain serial loop.
fn jobs_from_args(args: &Args) -> Result<usize> {
    match args.usize_opt("jobs")? {
        Some(0) => anyhow::bail!("--jobs must be >= 1"),
        Some(n) => Ok(n),
        None => Ok(crate::util::pool::default_jobs()),
    }
}

/// `rdlb bench`: run the campaign, write the report, optionally gate
/// against a baseline (non-zero exit on regression).
fn cmd_bench(args: &Args) -> Result<()> {
    let scale = BenchScale::parse(&args.str_or("scale", "quick"))
        .ok_or_else(|| anyhow!("unknown scale (smoke|quick|full)"))?;
    let mut settings = BenchSettings::new(scale, args.u64_or("seed", 1)?);
    settings.verbose = !args.bool_or("quiet", false)?;
    settings.jobs = jobs_from_args(args)?;
    if let Some(list) = args.get("runtimes") {
        let mut runtimes = Vec::new();
        for word in list.split(',') {
            let kind = RuntimeKind::parse(word)
                .ok_or_else(|| anyhow!("unknown runtime {word:?} in --runtimes"))?;
            if !runtimes.contains(&kind) {
                runtimes.push(kind);
            }
        }
        anyhow::ensure!(!runtimes.is_empty(), "--runtimes must name at least one runtime");
        settings.runtimes = runtimes;
    }

    let report = run_campaign(&settings)?;
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(next_bench_path);
    std::fs::write(&out, report.to_json_string())
        .with_context(|| format!("write {}", out.display()))?;
    println!(
        "bench: wrote {} ({} cases, {:.1} s wall{})",
        out.display(),
        report.cases.len(),
        report.total_wall_s(),
        report
            .sim_events_per_s()
            .map(|e| format!(", sim {:.2} M events/s", e / 1e6))
            .unwrap_or_default()
    );

    if let Some(baseline_path) = args.get("compare") {
        let text = std::fs::read_to_string(baseline_path)
            .with_context(|| format!("read baseline {baseline_path}"))?;
        let baseline = CampaignReport::from_json_str(&text)?;
        let uniform = args.f64_or("threshold", 0.25)?;
        let thresholds = Thresholds {
            wall_frac: args.f64_or("wall-threshold", uniform)?,
            events_frac: args.f64_or("events-threshold", uniform)?,
            ..Thresholds::default()
        };
        let cmp = compare_reports(&report, &baseline, &thresholds);
        print!("{}", cmp.summary());
        anyhow::ensure!(
            cmp.passed(),
            "bench regression vs {baseline_path}: {} regressions, {} missing cases",
            cmp.regressions.len(),
            cmp.missing_cases.len()
        );
        println!("bench: no regression vs {baseline_path}");
    }
    Ok(())
}

/// `rdlb chaos`: seeded fault-schedule fuzzing with the invariant oracle,
/// or deterministic replay of a shrunk reproducer.
fn cmd_chaos(args: &Args) -> Result<()> {
    if let Some(path) = args.get("replay") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read chaos schedule {path}"))?;
        let (sc, runs, checks, violations) = chaos::replay::replay_str(&text)?;
        println!("chaos replay: {}", sc.label());
        for run in &runs {
            let o = &run.outcome;
            println!(
                "chaos replay: {} -> {} (finished {}/{}, digest {})",
                run.runtime,
                if o.completed() { "completed" } else if o.hung { "HUNG" } else { "incomplete" },
                o.finished,
                o.n,
                o.result_digest,
            );
        }
        for v in &violations {
            println!("chaos replay: VIOLATION {v}");
        }
        println!(
            "chaos replay: {} runtime run(s), {} checks, {} violation(s)",
            runs.len(),
            checks,
            violations.len()
        );
        anyhow::ensure!(
            violations.is_empty(),
            "replayed schedule violates {} invariant(s)",
            violations.len()
        );
        return Ok(());
    }

    let budget = ChaosBudget::parse(&args.str_or("budget", "quick"))
        .ok_or_else(|| anyhow!("unknown budget (quick|deep|<scenario count>)"))?;
    let mut settings = ChaosSettings::new(args.u64_or("seed", 1)?, budget);
    settings.jobs = jobs_from_args(args)?;
    settings.out_dir = Some(PathBuf::from(args.str_or("out-dir", ".")));
    settings.shrink_budget = args.usize_or("shrink-budget", 64)?;
    settings.verbose = !args.bool_or("quiet", false)?;
    settings.hier = args.bool_or("hier", false)?;
    settings.journal_oracle = args.bool_or("journal-oracle", false)?;
    settings.master_kill = args.bool_or("master-kill", false)?;
    settings.stall = args.bool_or("stall", false)?;
    settings.partition = args.bool_or("partition", false)?;
    let outcome = chaos::run_chaos(&settings)?;
    println!("{}", outcome.summary());
    if !outcome.passed() {
        for case in &outcome.failures {
            println!("chaos: failing schedule {}:", case.original.label());
            for v in &case.violations {
                println!("chaos:   {v}");
            }
            if let Some(p) = &case.path {
                println!(
                    "chaos:   reproducer: {} (rdlb chaos --replay {})",
                    p.display(),
                    p.display()
                );
            }
        }
        anyhow::bail!(
            "chaos campaign found {} invariant-violating schedule(s)",
            outcome.failures.len()
        );
    }
    Ok(())
}

/// Dispatch a parsed command line to its subcommand driver.  Returns the
/// process outcome; unknown subcommands print usage and exit non-zero.
pub fn execute(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("bench") => cmd_bench(args),
        Some("chaos") => cmd_chaos(args),
        Some("experiment") => cmd_experiment(args),
        Some("trace") => cmd_trace(args),
        Some("trace-export") => cmd_trace_export(args),
        Some("theory") => cmd_theory(args),
        Some("native") => cmd_native(args),
        Some("serve") => cmd_serve(args),
        Some("worker") => cmd_worker(args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn run_flags_build_a_sim_config_by_default() {
        let cfg = run_config_from_args(&parse(&["run"])).unwrap();
        assert_eq!(cfg.runtime, RuntimeKind::Sim);
        assert_eq!(cfg.pes(), 256);
        assert_eq!(cfg.app, AppKind::Mandelbrot);
        assert_eq!(cfg.technique, Technique::Fac);
        assert!(cfg.rdlb);
    }

    #[test]
    fn run_flags_select_the_hier_runtime_and_groups() {
        let cfg = run_config_from_args(&parse(&[
            "run", "--runtime", "hier", "--groups", "4", "--tasks", "500", "--seed", "9",
        ]))
        .unwrap();
        assert_eq!(cfg.runtime, RuntimeKind::Hier);
        assert_eq!(cfg.net.groups, 4);
        assert_eq!(cfg.pes(), 8, "real runtimes default to 8 PEs");
        assert_eq!(cfg.tasks, Some(500));
        assert_eq!(cfg.seed, 9);
        // Groups must divide P: 8 PEs cannot split into 3 groups.
        let bad = run_config_from_args(&parse(&["run", "--runtime", "hier", "--groups", "3"]));
        assert!(bad.is_err(), "indivisible group count must be rejected at parse time");
    }

    #[test]
    fn run_flags_reject_unknown_runtime() {
        assert!(run_config_from_args(&parse(&["run", "--runtime", "mpi"])).is_err());
    }

    #[test]
    fn run_real_runtimes_default_to_bounded_workloads() {
        let cfg = run_config_from_args(&parse(&["run", "--runtime", "native"])).unwrap();
        assert_eq!(cfg.runtime, RuntimeKind::Native);
        assert_eq!(cfg.tasks, Some(2048), "wall-clock runtimes cap the default N");
        assert_eq!(cfg.pes(), 8);
    }

    #[test]
    fn run_timeout_flag_overrides_net_settings() {
        let cfg =
            run_config_from_args(&parse(&["run", "--runtime", "net", "--timeout", "7"])).unwrap();
        assert_eq!(cfg.net.timeout_secs, 7);
    }

    #[test]
    fn health_flags_arm_and_tune_the_policy() {
        // Strictly opt-in: a plain run config carries the inert default.
        let cfg = run_config_from_args(&parse(&["run"])).unwrap();
        assert!(!cfg.health.enabled);

        // Bare --health arms the defaults.
        let cfg = run_config_from_args(&parse(&["run", "--health"])).unwrap();
        assert!(cfg.health.enabled);
        assert_eq!(cfg.health.slack, HealthPolicy::on().slack);

        // Any knob implies arming and overrides its default.
        let cfg = run_config_from_args(&parse(&[
            "run", "--health-slack", "4.5", "--health-tick", "0.1", "--health-k", "3",
        ]))
        .unwrap();
        assert!(cfg.health.enabled, "tuning a knob implies --health");
        assert_eq!(cfg.health.slack, 4.5);
        assert_eq!(cfg.health.tick_secs, 0.1);
        assert_eq!(cfg.health.quarantine_k, 3);
        assert_eq!(cfg.health.floor_secs, HealthPolicy::on().floor_secs);

        // Config validation rejects a slack that would flag every chunk.
        assert!(run_config_from_args(&parse(&["run", "--health-slack", "0.5"])).is_err());
    }

    #[test]
    fn jobs_flag_defaults_to_every_core_and_rejects_zero() {
        // No flag: one worker per available core, never zero.
        let jobs = jobs_from_args(&parse(&["chaos"])).unwrap();
        assert_eq!(jobs, crate::util::pool::default_jobs());
        assert!(jobs >= 1);

        // Explicit counts pass through for both campaign subcommands.
        assert_eq!(jobs_from_args(&parse(&["chaos", "--jobs", "8"])).unwrap(), 8);
        assert_eq!(jobs_from_args(&parse(&["bench", "--jobs", "1"])).unwrap(), 1);

        // Zero workers can never drain the queue; garbage is a parse error.
        assert!(jobs_from_args(&parse(&["chaos", "--jobs", "0"])).is_err());
        assert!(jobs_from_args(&parse(&["bench", "--jobs", "many"])).is_err());
    }

    #[test]
    fn scenario_words_parse() {
        assert_eq!(parse_scenario("baseline", 8).unwrap(), Scenario::Baseline);
        assert_eq!(parse_scenario("failures:3", 8).unwrap(), Scenario::failures(3));
        assert!(matches!(parse_scenario("pe", 64).unwrap(), Scenario::PePerturb { .. }));
        assert!(matches!(
            parse_scenario("latency", 64).unwrap(),
            Scenario::LatencyPerturb { .. }
        ));
        assert!(matches!(parse_scenario("combined", 64).unwrap(), Scenario::Combined { .. }));
        assert_eq!(parse_scenario("stall", 64).unwrap(), Scenario::Stall { node: 3 });
        assert!(parse_scenario("bogus", 8).is_err());
    }
}
