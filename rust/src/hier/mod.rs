//! Two-level hierarchical runtime: rDLB over rDLB.
//!
//! The authors' follow-up work (*Two-level Dynamic Load Balancing for High
//! Performance Scientific Applications*, PAPERS.md) layers a coarse
//! scheduling level above the per-worker self-scheduling loop so the single
//! master stops being the scalability bottleneck.  This runtime is that
//! design expressed through the sans-I/O [`Engine`]:
//!
//! * a **root engine** treats each *group master* as one "worker" of a
//!   P = `groups` cluster and schedules coarse **super-chunks** of the
//!   iteration space across them with the ordinary DLS rule — including
//!   the rDLB re-dispatch phase, so a group master that fail-stops is
//!   tolerated exactly the way a worker failure is: its in-flight
//!   super-chunk evaporates and is re-dispatched to a surviving group;
//! * each group master runs a **fresh inner engine per super-chunk** over
//!   its `workers_per_group` OS-thread workers (a full rDLB instance in
//!   the super-chunk's local iteration space), so worker fail-stops,
//!   slowdowns and latency perturbations are absorbed *inside* the group
//!   without the root ever hearing about them.
//!
//! Fault model: global worker `w = g·W + l` (group `g`, local `l`).  A
//! fail-stop on a group's local slot 0 of a group `g > 0` is a **group
//! master** failure — the whole group (master half and workers) goes
//! silent.  Global worker 0 (group 0, local 0) is pristine, preserving the
//! paper's surviving-master assumption at both levels: group 0 always makes
//! progress, so with rDLB on, completion under a group-master fail-stop
//! plus up to W−1 worker failures in every surviving group remains a
//! theorem, not a race.
//!
//! Exactly-once attribution is layered: an inner engine attributes each
//! local iteration once within its group and the group reports one digest
//! per super-chunk position; the root engine's first-completion filter then
//! attributes each super-chunk position once globally, even when the rDLB
//! phase duplicated the super-chunk across groups.  Digest parity with the
//! serial kernel therefore holds bit-for-bit (the kernels' digests are
//! integer-valued, so the sums are order-independent).
//!
//! Useful/wasted-work accounting is layered the same way (groups report
//! their inner engine's split; the root's first-completion filter splits
//! only the useful share), with the same tail approximation every runtime
//! makes at `MPI_Abort`: compute still in flight when the run ends — a
//! flat runtime's unreported straggler chunk, or here a group's
//! half-finished super-chunk — is not folded into `Outcome::wasted_work`.
//!
//! No new wire frames: the hierarchical runtime is in-process (channels),
//! like [`crate::native`] — see `PROTOCOL.md` appendix A.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{
    Assignment, AssignmentId, Effect, Engine, EngineEvent, HealthPolicy, MasterConfig, SharedSink,
    TaskSet,
};
use crate::dls::{Technique, TechniqueParams};
use crate::native::{compute_chunk_with_faults, ComputeBackend};
use crate::sim::Outcome;

/// Parameters of one hierarchical execution.
#[derive(Clone)]
pub struct HierParams {
    /// Loop iterations N.
    pub n: usize,
    /// Group-master count G (the root's "workers").
    pub groups: usize,
    /// Workers per group W; total PEs = G × W.
    pub workers_per_group: usize,
    /// DLS rule used by the root (over super-chunks) and by every inner
    /// engine (over its super-chunk's iterations).
    pub technique: Technique,
    pub tech_params: TechniqueParams,
    /// Enable the rDLB re-dispatch phase on both levels.
    pub rdlb: bool,
    pub backend: ComputeBackend,
    /// Per **global** worker fail-stop time (index `g·W + l`); a time on a
    /// group's local slot 0 (for `g > 0`) fail-stops the whole group.
    /// Global worker 0 cannot fail.
    pub failures: Vec<Option<f64>>,
    /// Per global worker compute dilation factor (1.0 = nominal).
    pub slowdown: Vec<f64>,
    /// Per global worker extra one-way message latency, seconds.
    pub latency: Vec<f64>,
    /// Wall-clock hang bound for the whole run.
    pub timeout: Duration,
    /// Worker-health policy for the **root** engine: a group master whose
    /// super-chunk goes overdue is treated exactly like a straggling worker
    /// one level down — the super-chunk enters the root's speculative
    /// re-dispatch pool and a surviving group recomputes it before the
    /// final phase.  Inner engines always run with health disabled (their
    /// runs are one super-chunk long; intra-group stragglers are already
    /// absorbed by the inner rDLB phase).
    pub health: HealthPolicy,
    /// Observability tap installed on every engine of the hierarchy
    /// (`None` = no overhead): the root records with scope 0, group `g`'s
    /// inner engines with scope `1 + g`.
    pub sink: Option<SharedSink>,
}

impl HierParams {
    /// Defaults: healthy workers, 60 s hang bound.
    pub fn new(
        n: usize,
        groups: usize,
        workers_per_group: usize,
        technique: Technique,
        rdlb: bool,
        backend: ComputeBackend,
    ) -> Self {
        let total = groups * workers_per_group;
        HierParams {
            n,
            groups,
            workers_per_group,
            technique,
            tech_params: TechniqueParams::default(),
            rdlb,
            backend,
            failures: vec![None; total],
            slowdown: vec![1.0; total],
            latency: vec![0.0; total],
            timeout: Duration::from_secs(60),
            health: HealthPolicy::default(),
            sink: None,
        }
    }

    /// Total PEs G × W.
    pub fn total_workers(&self) -> usize {
        self.groups * self.workers_per_group
    }

    /// Install one global worker's full fault envelope — the single
    /// mapping point used by the experiments runner and the chaos harness
    /// (mirrors [`crate::native::NativeParams::set_fault_envelope`]).
    pub fn set_fault_envelope(
        &mut self,
        worker: usize,
        fail_after: Option<f64>,
        slowdown: f64,
        latency: f64,
    ) {
        self.failures[worker] = fail_after;
        self.slowdown[worker] = slowdown;
        self.latency[worker] = latency;
    }
}

/// The two-level runtime.
pub struct HierRuntime {
    params: HierParams,
}

/// Root → group-master messages.
enum ToGroup {
    Assign(Assignment),
    Terminate,
}

/// Group-master → root messages (a result piggy-backs the next request).
struct FromGroup {
    group: usize,
    /// `(root assignment id, useful compute seconds, wasted compute
    /// seconds, one digest per super-chunk position)` of a completed
    /// super-chunk.  The useful/wasted split comes from the inner engine
    /// (intra-group rDLB duplicates are waste even when the super-chunk's
    /// completion is the first one at the root), plus any stale-epoch
    /// leftovers burned since the previous report.
    result: Option<(AssignmentId, f64, f64, Vec<f64>)>,
}

/// Group-master → group-worker messages.  `epoch` identifies the inner run
/// (one per super-chunk) so leftover duplicate results from a previous run
/// cannot collide with the fresh engine's assignment ids.
enum ToGWorker {
    Assign { epoch: u64, id: AssignmentId, tasks: TaskSet },
    Terminate,
}

/// Group-worker → group-master messages.
struct FromGWorker {
    local: usize,
    epoch: u64,
    result: Option<(AssignmentId, f64, Vec<f64>)>,
}

impl HierRuntime {
    pub fn new(params: HierParams) -> Result<Self> {
        anyhow::ensure!(params.n >= 1, "no tasks");
        anyhow::ensure!(params.groups >= 1, "need at least one group");
        anyhow::ensure!(params.workers_per_group >= 1, "need at least one worker per group");
        let total = params.total_workers();
        anyhow::ensure!(params.failures.len() == total, "failures sized to G*W");
        anyhow::ensure!(params.slowdown.len() == total, "slowdown sized to G*W");
        anyhow::ensure!(params.latency.len() == total, "latency sized to G*W");
        anyhow::ensure!(
            params.failures[0].is_none(),
            "global worker 0 (group 0's master half) cannot fail"
        );
        Ok(HierRuntime { params })
    }

    /// Execute the run: the root loop on this thread, one group-master
    /// thread per group, W worker threads inside each group.
    pub fn run(&self) -> Result<Outcome> {
        let prm = &self.params;
        let groups = prm.groups;
        let wpg = prm.workers_per_group;
        let n = prm.n;
        // The root engine schedules super-chunks across group masters.
        let mut engine = Engine::new(MasterConfig {
            n,
            p: groups,
            technique: prm.technique,
            params: prm.tech_params.clone(),
            rdlb: prm.rdlb,
            health: prm.health.clone(),
        });
        if let Some(s) = prm.sink.clone() {
            engine.set_sink(0, Box::new(s));
        }

        let start = Instant::now();
        let hard_deadline = start + prm.timeout;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (to_root, root_rx) = mpsc::channel::<FromGroup>();
        let mut group_tx: Vec<mpsc::Sender<ToGroup>> = Vec::with_capacity(groups);
        let mut joins = Vec::with_capacity(groups);
        for g in 0..groups {
            let (tx, rx) = mpsc::channel::<ToGroup>();
            group_tx.push(tx);
            let ctx = GroupCtx {
                group: g,
                wpg,
                technique: prm.technique,
                tech_params: prm.tech_params.clone(),
                rdlb: prm.rdlb,
                backend: prm.backend.clone(),
                failures: prm.failures[g * wpg..(g + 1) * wpg].to_vec(),
                slowdown: prm.slowdown[g * wpg..(g + 1) * wpg].to_vec(),
                latency: prm.latency[g * wpg..(g + 1) * wpg].to_vec(),
                start,
                hard_deadline,
                shutdown: Arc::clone(&shutdown),
                sink: prm.sink.clone(),
            };
            let to_root = to_root.clone();
            joins.push(std::thread::spawn(move || ctx.run(rx, to_root)));
        }
        drop(to_root);

        // Root loop: the same thin driver shape as the native runtime, one
        // level up — group masters are its "workers".
        let mut reply: Vec<Effect> = Vec::with_capacity(1);
        // Root-level health timer: an overdue verdict here means a whole
        // super-chunk is speculatively re-dispatched to another group.
        let tick = Duration::from_secs_f64(prm.health.tick_secs.max(0.01));
        let mut next_tick = if prm.health.enabled { Some(start + tick) } else { None };
        loop {
            let left = hard_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                engine.handle(start.elapsed().as_secs_f64(), EngineEvent::Timeout, &mut reply);
                break;
            }
            let wait = match next_tick {
                Some(t) => left.min(t.saturating_duration_since(Instant::now())),
                None => left,
            };
            let msg = match root_rx.recv_timeout(wait) {
                Ok(m) => m,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // A tick or the hang bound elapsed; the `left.is_zero()`
                    // check above converts an expired bound into Timeout.
                    if let Some(t) = next_tick {
                        if Instant::now() >= t {
                            let now = start.elapsed().as_secs_f64();
                            reply.clear();
                            engine.handle(now, EngineEvent::HealthTick, &mut reply);
                            let woken: Vec<usize> = reply
                                .iter()
                                .filter_map(|e| match e {
                                    Effect::Wake { worker } => Some(*worker),
                                    _ => None,
                                })
                                .collect();
                            for gw in woken {
                                serve_group(&mut engine, gw, now, &mut reply, &group_tx);
                            }
                            next_tick = Some(Instant::now() + tick);
                        }
                    }
                    continue;
                }
                // Every group is gone: no further progress.
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    let now = start.elapsed().as_secs_f64();
                    engine.handle(now, EngineEvent::Timeout, &mut reply);
                    break;
                }
            };
            let now = start.elapsed().as_secs_f64();
            if let Some((id, useful, wasted, digests)) = msg.result {
                // Layered waste accounting: the group's inner waste
                // (intra-group rDLB duplicates, stale leftovers) is waste
                // no matter how the root classifies the super-chunk; only
                // the group's *useful* compute is handed to the root's
                // first-completion split, so a duplicated super-chunk
                // wastes exactly its useful part on top.
                engine.note_wasted(wasted);
                let completed = engine.on_result_with(now, msg.group, id, useful, &digests, |e, g| {
                    serve_group(e, g, now, &mut reply, &group_tx)
                });
                if completed {
                    break;
                }
            }
            // The message's own (initial or piggy-backed) request.
            serve_group(&mut engine, msg.group, now, &mut reply, &group_tx);
        }

        // MPI_Abort: stop every group (which stops its workers).  The
        // shutdown flag reaches group masters stuck waiting on workers that
        // fail-stopped while idle.
        shutdown.store(true, Ordering::Relaxed);
        for tx in &group_tx {
            let _ = tx.send(ToGroup::Terminate);
        }
        drop(group_tx);
        for j in joins {
            let _ = j.join();
        }

        let elapsed = start.elapsed().as_secs_f64();
        let hung = engine.hung();
        let stats = engine.final_stats();
        Ok(Outcome {
            parallel_time: if hung { f64::INFINITY } else { elapsed },
            hung,
            finished: engine.finished_count(),
            n,
            events: stats.requests + stats.completed_chunks,
            stats,
            wasted_work: engine.wasted_work(),
            useful_work: engine.useful_work(),
            failures: prm.failures.iter().filter(|f| f.is_some()).count(),
            result_digest: engine.result_digest(),
        })
    }
}

/// Feed one root-level `WorkerRequest` into the root engine and execute the
/// single effect.  A failed send is a group fail-stop in progress — the
/// super-chunk evaporates and the root, faithfully, does not react.
fn serve_group(
    engine: &mut Engine,
    group: usize,
    now: f64,
    reply: &mut Vec<Effect>,
    group_tx: &[mpsc::Sender<ToGroup>],
) {
    reply.clear();
    engine.handle(now, EngineEvent::WorkerRequest { worker: group }, reply);
    match reply.pop() {
        Some(Effect::Assign(a)) => {
            let _ = group_tx[group].send(ToGroup::Assign(a));
        }
        Some(Effect::TerminateWorker { worker }) => {
            let _ = group_tx[worker].send(ToGroup::Terminate);
        }
        // Park: the engine holds the group; its master simply blocks on its
        // channel until woken or terminated.
        _ => {}
    }
}

/// Everything one group-master thread needs.
struct GroupCtx {
    group: usize,
    wpg: usize,
    technique: Technique,
    tech_params: TechniqueParams,
    rdlb: bool,
    backend: ComputeBackend,
    /// Per **local** worker (this group's slice of the global plan).
    failures: Vec<Option<f64>>,
    slowdown: Vec<f64>,
    latency: Vec<f64>,
    start: Instant,
    hard_deadline: Instant,
    shutdown: Arc<AtomicBool>,
    /// The run's shared observability tap; inner engines record with scope
    /// `1 + group` so their events stay distinguishable from the root's.
    sink: Option<SharedSink>,
}

impl GroupCtx {
    /// The group-master loop: spawn this group's workers, then serve one
    /// inner rDLB run per super-chunk until terminated or fail-stopped.
    fn run(self, group_rx: mpsc::Receiver<ToGroup>, to_root: mpsc::Sender<FromGroup>) {
        let g = self.group;
        let wpg = self.wpg;
        let (to_group_master, worker_rx) = mpsc::channel::<FromGWorker>();
        let mut worker_tx: Vec<mpsc::Sender<ToGWorker>> = Vec::with_capacity(wpg);
        let mut joins = Vec::with_capacity(wpg);
        for l in 0..wpg {
            let (tx, rx) = mpsc::channel::<ToGWorker>();
            worker_tx.push(tx);
            let to_master = to_group_master.clone();
            let backend = self.backend.clone();
            let deadline = self.failures[l].map(|t| self.start + Duration::from_secs_f64(t));
            let slow = self.slowdown[l].max(1.0);
            let lat = Duration::from_secs_f64(self.latency[l].max(0.0));
            joins.push(std::thread::spawn(move || {
                group_worker(l, rx, to_master, backend, deadline, slow, lat)
            }));
        }
        drop(to_group_master);

        // A fail time on local slot 0 of a non-root group is a group-master
        // fail-stop: past it, this whole loop goes silent (in-flight
        // super-chunk evaporates; the root's rDLB phase recovers it).
        let master_deadline = if g > 0 {
            self.failures[0].map(|t| self.start + Duration::from_secs_f64(t))
        } else {
            None
        };
        let m_dead = |t: Instant| master_deadline.is_some_and(|d| t >= d);

        let mut epoch = 0u64;
        // Workers whose pending request outlived the previous inner run
        // (parked at its completion); served first in the next run.
        let mut pending = vec![false; wpg];
        // Compute burned by stale-epoch results (duplicates outliving
        // their super-chunk); folded into the next report's wasted share.
        let mut carry_wasted = 0.0f64;
        let mut reply: Vec<Effect> = Vec::with_capacity(1);

        // Every exit from this block — termination, fail-stop, hang bound,
        // root gone — falls through to the terminate/join epilogue below,
        // so worker threads never outlive the run (cf. the native runtime).
        if to_root.send(FromGroup { group: g, result: None }).is_ok() {
            'chunks: while let Ok(msg) = group_rx.recv() {
                let sup = match msg {
                    ToGroup::Terminate => break,
                    ToGroup::Assign(a) => a,
                };
                if m_dead(Instant::now()) {
                    break; // group-master fail-stop: the super-chunk evaporates
                }
                epoch += 1;
                let len = sup.len();
                // A fresh inner engine over the super-chunk's local
                // iteration space [0, len) — a complete rDLB instance
                // inside the group.
                let mut tp = self.tech_params.clone();
                tp.seed = tp.seed ^ ((g as u64) << 17) ^ epoch;
                let mut engine = Engine::new(MasterConfig {
                    n: len,
                    p: wpg,
                    technique: self.technique,
                    params: tp,
                    rdlb: self.rdlb,
                    // Inner runs are one super-chunk long; intra-group
                    // stragglers are the inner rDLB phase's job.
                    health: HealthPolicy::default(),
                });
                if let Some(s) = self.sink.clone() {
                    engine.set_sink(1 + g as u32, Box::new(s));
                }
                let mut chunk_digests = vec![0.0f64; len];
                // Local TaskSet per inner assignment (ids are sequential;
                // a Range — every primary chunk — stores as O(1) bounds).
                let mut issued: Vec<TaskSet> = Vec::new();

                for l in 0..wpg {
                    if std::mem::take(&mut pending[l]) {
                        let now = self.start.elapsed().as_secs_f64();
                        serve_local(
                            &mut engine,
                            l,
                            now,
                            epoch,
                            &sup,
                            &mut issued,
                            &mut reply,
                            &worker_tx,
                        );
                    }
                }

                while !engine.is_complete() {
                    let left = self.hard_deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        break 'chunks; // global hang bound: the run is over
                    }
                    // Tick instead of sleeping the full bound: a group
                    // whose workers all fail-stopped while idle would
                    // otherwise hold the root's final join until the bound.
                    let tick = left.min(Duration::from_millis(20));
                    let wmsg = match worker_rx.recv_timeout(tick) {
                        Ok(m) => m,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if self.shutdown.load(Ordering::Relaxed) || m_dead(Instant::now()) {
                                break 'chunks;
                            }
                            continue;
                        }
                        // Every worker of this group is gone: the
                        // super-chunk can never complete here — go silent
                        // so the root's rDLB phase re-dispatches it.
                        Err(mpsc::RecvTimeoutError::Disconnected) => break 'chunks,
                    };
                    if m_dead(Instant::now()) {
                        break 'chunks; // died mid-super-chunk
                    }
                    let now = self.start.elapsed().as_secs_f64();
                    if let Some((id, compute, digests)) = wmsg.result {
                        if wmsg.epoch == epoch {
                            // Record digests for every reported position:
                            // a duplicate overwrites with the identical
                            // value (the kernels are deterministic); the
                            // root engine's first-completion filter
                            // provides the global exactly-once guarantee.
                            if let Some(local_ids) = issued.get(id as usize) {
                                for (pos, lt) in local_ids.iter().enumerate() {
                                    if let Some(&d) = digests.get(pos) {
                                        chunk_digests[lt as usize] = d;
                                    }
                                }
                            }
                            let done = engine.on_result_with(
                                now,
                                wmsg.local,
                                id,
                                compute,
                                &digests,
                                |e, w| {
                                    serve_local(
                                        e,
                                        w,
                                        now,
                                        epoch,
                                        &sup,
                                        &mut issued,
                                        &mut reply,
                                        &worker_tx,
                                    )
                                },
                            );
                            if done {
                                // The reporter's piggy-backed request was
                                // not served; it carries to the next run.
                                pending[wmsg.local] = true;
                                break;
                            }
                        } else {
                            // A stale-epoch result (a leftover rDLB
                            // duplicate from an earlier super-chunk)
                            // carries no work for this run — its compute
                            // is pure waste, reported with the next
                            // super-chunk — but its piggy-backed request
                            // is live: fall through and serve it.
                            carry_wasted += compute;
                        }
                    }
                    serve_local(
                        &mut engine,
                        wmsg.local,
                        now,
                        epoch,
                        &sup,
                        &mut issued,
                        &mut reply,
                        &worker_tx,
                    );
                }

                // Requests parked at completion carry over to the next run.
                for &l in engine.parked() {
                    pending[l as usize] = true;
                }
                if m_dead(Instant::now()) {
                    break; // died before reporting the super-chunk
                }
                // Report the completed super-chunk (one digest per
                // position) with the inner engine's useful/wasted split —
                // intra-group duplicates are waste regardless of how the
                // root classifies the super-chunk; this piggy-backs the
                // group's next request.
                let wasted = engine.wasted_work() + std::mem::take(&mut carry_wasted);
                let result = Some((sup.id, engine.useful_work(), wasted, chunk_digests));
                if to_root.send(FromGroup { group: g, result }).is_err() {
                    break; // root gone: the MPI_Abort path
                }
            }
        }

        for tx in &worker_tx {
            let _ = tx.send(ToGWorker::Terminate);
        }
        drop(worker_tx);
        for j in joins {
            let _ = j.join();
        }
    }
}

/// Feed one local `WorkerRequest` into the inner engine and execute the
/// single effect: translate the local chunk into global task ids and send
/// it to the worker thread.  A failed send is a worker fail-stop — the
/// chunk evaporates; the inner rDLB phase recovers it.
#[allow(clippy::too_many_arguments)]
fn serve_local(
    engine: &mut Engine,
    worker: usize,
    now: f64,
    epoch: u64,
    sup: &Assignment,
    issued: &mut Vec<TaskSet>,
    reply: &mut Vec<Effect>,
    worker_tx: &[mpsc::Sender<ToGWorker>],
) {
    reply.clear();
    engine.handle(now, EngineEvent::WorkerRequest { worker }, reply);
    if let Some(Effect::Assign(a)) = reply.pop() {
        debug_assert_eq!(issued.len(), a.id as usize, "inner assignment ids are sequential");
        let tasks = to_global(&sup.tasks, &a.tasks);
        // Keep the local TaskSet for position→local-id mapping: a Range —
        // every primary chunk — stores as O(1) bounds, no id list.
        issued.push(a.tasks);
        let _ = worker_tx[worker].send(ToGWorker::Assign { epoch, id: a.id, tasks });
    }
    // Park: the worker blocks on its channel.  TerminateWorker cannot occur
    // here: the inner loop only serves requests while the run is incomplete
    // (and the persistent workers outlive each inner run regardless).
}

/// Map a chunk in the super-chunk's local iteration space `[0, len)` onto
/// global task ids.  Ascending in, ascending out.
fn to_global(sup: &TaskSet, local: &TaskSet) -> TaskSet {
    match (sup, local) {
        (TaskSet::Range { start, .. }, TaskSet::Range { start: ls, end: le }) => {
            TaskSet::Range { start: start + ls, end: start + le }
        }
        (TaskSet::Range { start, .. }, TaskSet::List(v)) => {
            TaskSet::List(v.iter().map(|l| start + l).collect())
        }
        (TaskSet::List(ids), TaskSet::Range { start: ls, end: le }) => {
            TaskSet::List(ids[*ls as usize..*le as usize].to_vec())
        }
        (TaskSet::List(ids), TaskSet::List(v)) => {
            TaskSet::List(v.iter().map(|&l| ids[l as usize]).collect())
        }
    }
}

/// One group worker: the same request–compute–report loop as the native
/// runtime's workers — the per-chunk fault semantics are literally shared
/// ([`compute_chunk_with_faults`]) — with the inner-run epoch echoed back
/// so the group master can tell live results from leftovers of a finished
/// super-chunk.
fn group_worker(
    local: usize,
    rx: mpsc::Receiver<ToGWorker>,
    to_master: mpsc::Sender<FromGWorker>,
    backend: ComputeBackend,
    deadline: Option<Instant>,
    slow: f64,
    lat: Duration,
) {
    let dead = |t: Instant| deadline.is_some_and(|d| t >= d);
    if !lat.is_zero() {
        std::thread::sleep(lat); // delayed initial request
    }
    if to_master.send(FromGWorker { local, epoch: 0, result: None }).is_err() {
        return;
    }
    while let Ok(msg) = rx.recv() {
        match msg {
            ToGWorker::Terminate => break,
            ToGWorker::Assign { epoch, id, tasks } => {
                let Some((compute, digests)) =
                    compute_chunk_with_faults(&backend, &tasks, &dead, slow, lat)
                else {
                    return; // fail-stop: chunk evaporates
                };
                let msg = FromGWorker { local, epoch, result: Some((id, compute, digests)) };
                if to_master.send(msg).is_err() {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{CostModel, MandelbrotApp};
    use std::sync::Arc;

    fn synthetic(n: usize, cost: f64) -> ComputeBackend {
        ComputeBackend::Synthetic {
            model: Arc::new(CostModel::from_costs(vec![cost; n])),
            scale: 1.0,
        }
    }

    #[test]
    fn baseline_completes_with_exact_digest() {
        let n = 200;
        let p = HierParams::new(n, 2, 3, Technique::Fac, true, synthetic(n, 1e-4));
        let o = HierRuntime::new(p).unwrap().run().unwrap();
        assert!(o.completed(), "{o:?}");
        assert_eq!(o.finished, n);
        assert_eq!(o.result_digest, n as f64, "synthetic digest is 1.0 per task");
        assert!(o.stats.identity_violations().is_empty(), "{:?}", o.stats);
    }

    #[test]
    fn single_group_degenerates_to_flat_rdlb() {
        let n = 96;
        let p = HierParams::new(n, 1, 4, Technique::Gss, true, synthetic(n, 1e-4));
        let o = HierRuntime::new(p).unwrap().run().unwrap();
        assert!(o.completed(), "{o:?}");
        assert_eq!(o.result_digest, n as f64);
    }

    #[test]
    fn group_master_failure_is_recovered_by_root_redispatch() {
        let n = 160;
        let mut p = HierParams::new(n, 2, 2, Technique::Fac, true, synthetic(n, 2e-3));
        // Global worker 2 = group 1, local 0: a group-master fail-stop.
        p.failures[2] = Some(0.05);
        p.timeout = Duration::from_secs(30);
        let o = HierRuntime::new(p).unwrap().run().unwrap();
        assert!(o.completed(), "group death must be absorbed: {o:?}");
        assert_eq!(o.finished, n);
        assert_eq!(o.result_digest, n as f64);
        assert_eq!(o.failures, 1);
    }

    #[test]
    fn root_health_flags_dead_groups_superchunk() {
        // Group 1's master dies holding a super-chunk.  With root-level
        // health armed, the root flags the chunk overdue (speculative
        // re-dispatch) instead of waiting for the final phase — the run
        // completes and the overdue counter proves the early detection.
        let n = 160;
        let mut p = HierParams::new(n, 2, 2, Technique::Fac, true, synthetic(n, 2e-3));
        p.failures[2] = Some(0.05);
        p.timeout = Duration::from_secs(30);
        p.health = HealthPolicy {
            slack: 1.5,
            floor_secs: 0.01,
            tick_secs: 0.02,
            ..HealthPolicy::on()
        };
        let o = HierRuntime::new(p).unwrap().run().unwrap();
        assert!(o.completed(), "group death must be absorbed: {o:?}");
        assert_eq!(o.finished, n);
        assert_eq!(o.result_digest, n as f64);
        assert!(o.stats.overdue_chunks > 0, "dead group's super-chunk must go overdue: {:?}", o.stats);
        assert!(o.stats.identity_violations().is_empty(), "{:?}", o.stats);
    }

    #[test]
    fn failure_without_rdlb_hangs_until_timeout() {
        let n = 120;
        let mut p = HierParams::new(n, 2, 2, Technique::Fac, false, synthetic(n, 2e-3));
        p.failures[2] = Some(0.03);
        p.timeout = Duration::from_millis(900);
        let o = HierRuntime::new(p).unwrap().run().unwrap();
        assert!(o.hung, "group death without rDLB must hang: {o:?}");
        assert!(o.parallel_time.is_infinite());
    }

    #[test]
    fn mandelbrot_digest_matches_serial_kernel() {
        let app = MandelbrotApp { width: 16, height: 16, max_iter: 32, ..Default::default() };
        let n = app.n_tasks();
        let serial: f64 = app.compute_range(0, n as u32).iter().map(|&c| c as f64).sum();
        let backend = ComputeBackend::Mandelbrot(Arc::new(app));
        let o = HierRuntime::new(HierParams::new(n, 2, 2, Technique::Gss, true, backend))
            .unwrap()
            .run()
            .unwrap();
        assert!(o.completed(), "{o:?}");
        assert_eq!(o.result_digest, serial, "hier ↔ serial digest parity");
    }

    #[test]
    fn rejects_bad_shapes() {
        let p = HierParams::new(10, 2, 2, Technique::Ss, true, synthetic(10, 1e-4));
        let mut bad = p.clone();
        bad.failures[0] = Some(0.1);
        assert!(HierRuntime::new(bad).is_err(), "global worker 0 must never fail");
        let mut bad = p.clone();
        bad.failures.pop();
        assert!(HierRuntime::new(bad).is_err(), "fault plan must be sized to G*W");
        assert!(HierRuntime::new(p).is_ok());
    }
}
