//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environment for this repository is fully offline, so instead of
//! pulling `anyhow` from crates.io this vendored crate re-implements exactly
//! the API subset the `rdlb` crate uses:
//!
//! * [`Error`] — an opaque, context-carrying error value (`Send + Sync`);
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a defaulted error
//!   type parameter;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` *and*
//!   `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros;
//! * a blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts standard errors.
//!
//! Mirroring the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket `From` impl and the
//! twin `Context` impls coherent.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with a defaulted, boxed-context error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a stack of human-readable context strings (outermost
/// first) over an optional underlying `std::error::Error` source.
pub struct Error {
    context: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { context: vec![message.to_string()], source: None }
    }

    /// Attach an outer context layer.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.insert(0, context.to_string());
        self
    }

    /// The outermost message followed by every deeper layer, ending with the
    /// source error (if any).
    pub fn chain(&self) -> Vec<String> {
        let mut out = self.context.clone();
        if let Some(src) = &self.source {
            out.push(src.to_string());
        }
        if out.is_empty() {
            out.push("unknown error".to_string());
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.first() {
            Some(outer) => f.write_str(outer),
            None => match &self.source {
                Some(src) => write!(f, "{src}"),
                None => f.write_str("unknown error"),
            },
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)?;
        let chain = self.chain();
        let causes = &chain[1..];
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error { context: Vec::new(), source: Some(Box::new(err)) }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Wrap the error (or the absent value) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let v: u32 = "not a number".parse()?;
            Ok(v)
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("invalid digit"), "{err:?}");
    }

    #[test]
    fn context_layers_display_and_debug() {
        let err: Result<()> = Err(io_err());
        let err = err.context("reading config").unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        let debug = format!("{err:?}");
        assert!(debug.contains("reading config") && debug.contains("missing thing"), "{debug}");
    }

    #[test]
    fn option_context() {
        let missing: Option<u32> = None;
        let err = missing.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(err.to_string(), "no value 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {x}"))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
    }

    #[test]
    fn bare_ensure() {
        fn f(x: u32) -> Result<()> {
            ensure!(x > 0);
            Ok(())
        }
        assert!(f(0).unwrap_err().to_string().contains("x > 0"));
        assert!(f(1).is_ok());
    }
}
