//! Cross-runtime determinism guarantees — what makes bench numbers and CI
//! regression gating trustworthy:
//!
//!  * simulator: the complete outcome (virtual time, counters, events) is a
//!    pure function of the seeded config, across repeated runs and across
//!    the replication fan-out thread count;
//!  * wall-clock runtimes: wall times race, but the **result digest**
//!    attributes exactly one value per iteration, so it is identical across
//!    repeated runs and across worker counts, even under failures and rDLB
//!    duplicate completions.

use std::sync::Arc;
use std::time::Duration;

use rdlb::apps::{AppKind, MandelbrotApp};
use rdlb::config::{ExperimentConfig, Scenario};
use rdlb::dls::Technique;
use rdlb::experiments::{run_cell, run_outcome};
use rdlb::native::{ComputeBackend, NativeParams, NativeRuntime};
use rdlb::net::{run_loopback, NetMasterParams};

fn sim_cfg(seed: u64) -> ExperimentConfig {
    ExperimentConfig::builder()
        .app(AppKind::Uniform)
        .tasks(2_000)
        .pes(8)
        .technique(Technique::Fac)
        .rdlb(true)
        .scenario(Scenario::failures(4))
        .seed(seed)
        .replications(4)
        .build()
        .unwrap()
}

#[test]
fn sim_outcome_identical_across_repeated_runs() {
    let cfg = sim_cfg(42);
    let a = run_outcome(&cfg, 0, 1.0).unwrap();
    let b = run_outcome(&cfg, 0, 1.0).unwrap();
    assert!(a.completed());
    assert_eq!(a.parallel_time, b.parallel_time);
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.events, b.events);
    assert_eq!(a.finished, b.finished);
    assert!(a.events > 0);
    // A different replication draws a different failure plan.
    let c = run_outcome(&cfg, 1, 1.0).unwrap();
    assert_ne!(
        (a.parallel_time, a.events),
        (c.parallel_time, c.events),
        "replications must differ"
    );
}

#[test]
fn sim_cell_identical_across_thread_counts() {
    let cfg = sim_cfg(7);
    let one = run_cell(&cfg, 1).unwrap();
    let many = run_cell(&cfg, 8).unwrap();
    assert_eq!(one.reps, many.reps);
    assert_eq!(one.mean_time, many.mean_time, "thread fan-out changed the mean");
    assert_eq!(one.std_time, many.std_time);
    assert_eq!(one.hung_fraction, many.hung_fraction);
    assert_eq!(one.mean_waste, many.mean_waste);
    assert_eq!(one.mean_rescheduled, many.mean_rescheduled);
    assert_eq!(one.mean_events, many.mean_events);
    assert!(one.mean_events > 0.0);
}

/// Mandelbrot escape counts give every iteration a distinct value, so the
/// digest detects both lost and double-counted iterations.
fn mandelbrot_digest(workers: usize) -> f64 {
    let app = MandelbrotApp { width: 32, height: 32, max_iter: 64, ..Default::default() };
    let n = app.n_tasks();
    let backend = ComputeBackend::Mandelbrot(Arc::new(app));
    let mut params = NativeParams::new(n, workers, Technique::Fac, true, backend);
    params.timeout = Duration::from_secs(60);
    params = params.with_failures(1, 0.02);
    let outcome = NativeRuntime::new(params).unwrap().run().unwrap();
    assert!(outcome.completed(), "P={workers}: {outcome:?}");
    outcome.result_digest
}

#[test]
fn native_digest_invariant_across_runs_and_worker_counts() {
    let a = mandelbrot_digest(2);
    let b = mandelbrot_digest(2);
    let c = mandelbrot_digest(4);
    assert!(a > 0.0);
    assert_eq!(a, b, "same run twice must agree exactly");
    assert_eq!(a, c, "digest must not depend on the worker count");
}

/// The v2 range-native fast path must not change what is computed: the
/// native and net-loopback runtimes (range-native `Assign` frames,
/// `compute_into` chunk execution) must both reproduce the serial kernel's
/// digest bit-for-bit, with failures forcing rDLB re-dispatch (and its
/// explicit-list chunks) into the mix.
#[test]
fn v2_fast_path_digest_parity_native_net_serial() {
    let app = MandelbrotApp { width: 48, height: 48, max_iter: 128, ..Default::default() };
    let n = app.n_tasks();
    // Ground truth through the range-native kernel entry point.
    let serial: f64 = app.compute_range(0, n as u32).iter().map(|&c| c as f64).sum();
    // ...which must itself agree with the explicit-list kernel path.
    let ids: Vec<u32> = (0..n as u32).collect();
    let by_list: f64 = app.compute_chunk(&ids).iter().map(|&c| c as f64).sum();
    assert_eq!(serial, by_list);

    let backend = ComputeBackend::Mandelbrot(Arc::new(app));
    let mut np = NativeParams::new(n, 4, Technique::Fac, true, backend.clone());
    np.timeout = Duration::from_secs(60);
    np = np.with_failures(2, 0.05);
    let native = NativeRuntime::new(np).unwrap().run().unwrap();
    assert!(native.completed(), "{native:?}");

    let mut params =
        NetMasterParams::new(n, 4, Technique::Fac, true).with_failures(2, 0.05).unwrap();
    params.timeout = Duration::from_secs(60);
    let (net, _) = run_loopback(params, &backend).unwrap();
    assert!(net.completed(), "{net:?}");

    // Escape counts are integer-valued: the sums are exact, so any lost or
    // double-counted iteration (e.g. an rDLB duplicate contributing twice)
    // breaks equality outright.
    assert_eq!(native.result_digest, serial, "native ↔ serial digest parity");
    assert_eq!(net.result_digest, serial, "net-loopback ↔ serial digest parity");
}

#[test]
fn net_loopback_digest_counts_each_iteration_once() {
    // Synthetic digests are 1.0 per iteration: the total must be exactly N
    // on every run, even when failures force rDLB duplicates.
    let n = 200;
    let mk = || {
        let mut params = NetMasterParams::new(n, 4, Technique::Fac, true)
            .with_failures(3, 0.05)
            .unwrap();
        params.timeout = Duration::from_secs(30);
        let backend = ComputeBackend::Synthetic {
            model: Arc::new(rdlb::apps::CostModel::from_costs(vec![2e-3; n])),
            scale: 1.0,
        };
        let (outcome, _) = run_loopback(params, &backend).unwrap();
        assert!(outcome.completed(), "{outcome:?}");
        outcome.result_digest
    };
    assert_eq!(mk(), n as f64);
    assert_eq!(mk(), n as f64);
}
