//! Determinism-under-parallelism: the campaign harnesses must produce
//! byte-identical results at any `--jobs` count.
//!
//! The executor (`util::pool::for_each_ordered`) computes results
//! concurrently but folds them in canonical index order, and shrinking
//! stays single-threaded — so every observable artifact (summaries,
//! counters, shrunk reproducers, report digests) is a pure function of
//! `(seed, budget)` regardless of worker count.  These tests pin that
//! contract end-to-end; they are also the executor-heavy suites the TSan
//! CI job runs to hunt data races under real contention.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use rdlb::bench::{run_campaign, BenchScale, BenchSettings};
use rdlb::chaos::{run_chaos, scenario_to_json_string, BugHook, ChaosBudget, ChaosSettings};
use rdlb::config::RuntimeKind;
use rdlb::util::{for_each_ordered, Watchdog};

fn chaos_settings(seed: u64, scenarios: usize, jobs: usize) -> ChaosSettings {
    let mut s = ChaosSettings::new(seed, ChaosBudget { scenarios });
    s.jobs = jobs;
    s
}

/// A clean chaos campaign reports identical counters and summary text at
/// every job count.
#[test]
fn chaos_campaign_is_identical_at_any_job_count() {
    let _wd = Watchdog::arm("parallel chaos determinism", Duration::from_secs(300));
    let serial = run_chaos(&chaos_settings(5, 24, 1)).unwrap();
    assert!(serial.passed(), "clean build must pass: {:?}", serial.failures);
    for jobs in [2, 4, 8] {
        let parallel = run_chaos(&chaos_settings(5, 24, jobs)).unwrap();
        assert_eq!(
            (parallel.scenarios, parallel.runs, parallel.checks),
            (serial.scenarios, serial.runs, serial.checks),
            "counters drifted at jobs={jobs}"
        );
        assert_eq!(parallel.summary(), serial.summary(), "summary drifted at jobs={jobs}");
        assert!(parallel.passed());
    }
}

/// A buggy campaign shrinks every failure to the same minimal reproducer
/// in parallel as in serial — shrinking is single-threaded and folds run
/// in canonical order, so the JSON artifacts match byte-for-byte.
#[test]
fn chaos_bug_campaign_shrinks_to_identical_reproducers() {
    let _wd = Watchdog::arm("parallel chaos shrinking", Duration::from_secs(300));
    let settings = |jobs| {
        let mut s = chaos_settings(2, 16, jobs);
        s.bug = Some(BugHook::DropOneRedispatch);
        s.shrink_budget = 24;
        s
    };
    let serial = run_chaos(&settings(1)).unwrap();
    assert!(!serial.passed(), "the armed bug must be detected");
    for jobs in [4, 8] {
        let parallel = run_chaos(&settings(jobs)).unwrap();
        assert_eq!(parallel.failures.len(), serial.failures.len());
        for (p, s) in parallel.failures.iter().zip(&serial.failures) {
            assert_eq!(
                scenario_to_json_string(&p.original),
                scenario_to_json_string(&s.original),
                "original schedule drifted at jobs={jobs}"
            );
            assert_eq!(
                scenario_to_json_string(&p.shrunk),
                scenario_to_json_string(&s.shrunk),
                "shrunk reproducer drifted at jobs={jobs}"
            );
        }
    }
}

/// The bench campaign's outcome metrics and case order are identical at
/// any job count (wall-clock fields vary run to run and are excluded by
/// the deterministic digest).
#[test]
fn bench_campaign_digest_is_identical_at_any_job_count() {
    let _wd = Watchdog::arm("parallel bench determinism", Duration::from_secs(300));
    let settings = |jobs| {
        let mut s = BenchSettings::new(BenchScale::smoke(), 7);
        s.runtimes = vec![RuntimeKind::Sim];
        s.jobs = jobs;
        s
    };
    let serial = run_campaign(&settings(1)).unwrap();
    for jobs in [2, 8] {
        let parallel = run_campaign(&settings(jobs)).unwrap();
        assert_eq!(
            parallel.deterministic_digest(),
            serial.deterministic_digest(),
            "outcome digest drifted at jobs={jobs}"
        );
        assert_eq!(
            parallel.cases.iter().map(|c| c.id.clone()).collect::<Vec<_>>(),
            serial.cases.iter().map(|c| c.id.clone()).collect::<Vec<_>>(),
            "case order drifted at jobs={jobs}"
        );
    }
}

/// Executor stress under real contention: many tiny items on many
/// workers, each ran exactly once, emitted strictly in input order.
/// (This is the suite TSan leans on — small work items maximize
/// queue/slot churn.)
#[test]
fn executor_stress_emits_in_order_under_contention() {
    let _wd = Watchdog::arm("executor stress", Duration::from_secs(120));
    let ran = AtomicUsize::new(0);
    let mut emitted = Vec::new();
    for_each_ordered(
        (0..500usize).collect::<Vec<_>>(),
        8,
        |i| {
            ran.fetch_add(1, Ordering::Relaxed);
            i * 3
        },
        |idx, r| emitted.push((idx, r)),
    );
    assert_eq!(ran.load(Ordering::Relaxed), 500);
    assert_eq!(emitted.len(), 500);
    for (pos, (idx, r)) in emitted.iter().enumerate() {
        assert_eq!((pos, pos * 3), (*idx, *r));
    }
}
