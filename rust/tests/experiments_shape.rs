//! Shape tests over the experiment drivers: run the figure pipelines at
//! smoke scale and assert the qualitative results the paper reports
//! (DESIGN.md §6 "expected shape").

use rdlb::apps::AppKind;
use rdlb::experiments::{
    cells_to_csv, fig3_failures, fig3_perturbations, fig4_resilience, fig5_flexibility,
    perturb_to_csv, robustness_to_csv, Scale,
};

fn smoke() -> Scale {
    let mut s = Scale::smoke();
    s.reps = 2;
    s
}

#[test]
fn fig3_failures_all_cells_complete() {
    let data = fig3_failures(AppKind::Uniform, &smoke()).unwrap();
    // 13 techniques × 4 scenarios.
    assert_eq!(data.cells.len(), 13 * 4);
    for c in &data.cells {
        assert_eq!(c.hung_fraction, 0.0, "{} {} hung with rDLB", c.technique, c.scenario);
        assert!(c.mean_time.is_finite(), "{} {}", c.technique, c.scenario);
        assert!(c.rdlb);
    }
    // CSV renders every cell.
    let csv = cells_to_csv(&data.cells);
    assert_eq!(csv.lines().count(), 1 + data.cells.len());
}

#[test]
fn fig3_failure_cost_increases_with_failure_count() {
    let data = fig3_failures(AppKind::Uniform, &smoke()).unwrap();
    // For each technique: T(P-1 failures) >= T(baseline).
    for technique in ["FAC", "SS", "GSS"] {
        let t = |scenario: &str| {
            data.cells
                .iter()
                .find(|c| c.technique == technique && c.scenario == scenario)
                .unwrap()
                .mean_time
        };
        let baseline = t("baseline");
        let worst = t("15-failures"); // smoke scale = 16 PEs ⇒ P−1 = 15
        assert!(
            worst > baseline,
            "{technique}: P-1 failures ({worst}) not worse than baseline ({baseline})"
        );
    }
}

#[test]
fn fig4_resilience_most_robust_is_one() {
    let data = fig3_failures(AppKind::Uniform, &smoke()).unwrap();
    let tables = fig4_resilience(&data);
    assert_eq!(tables.len(), 3, "three failure scenarios");
    for t in &tables {
        let min_rho = t
            .rows
            .iter()
            .map(|r| r.rho)
            .filter(|r| r.is_finite())
            .fold(f64::INFINITY, f64::min);
        assert!((min_rho - 1.0).abs() < 1e-9, "{}: min ρ {min_rho}", t.scenario);
        assert_eq!(t.rows.len(), 13);
        for r in &t.rows {
            assert!(r.rho >= 1.0 - 1e-9, "{} ρ {}", r.technique, r.rho);
        }
    }
    let csv = robustness_to_csv(&tables);
    assert!(csv.lines().count() > 13 * 3);
}

#[test]
fn fig5_flexibility_rdlb_improves_latency_scenarios() {
    let cells = fig3_perturbations(AppKind::Uniform, &smoke()).unwrap();
    // Shape (v): under latency/combined perturbation, rDLB times are no
    // worse on aggregate (and typically much better).
    let mut speedups = Vec::new();
    for c in &cells {
        if c.scenario.contains("latency") || c.scenario.contains("combined") {
            let tw = c.without_rdlb.time_or_inf();
            let tr = c.with_rdlb.time_or_inf();
            if tw.is_finite() && tr.is_finite() && tr > 0.0 {
                speedups.push(tw / tr);
            }
        }
    }
    assert!(!speedups.is_empty());
    let mean_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    assert!(
        mean_speedup > 1.0,
        "rDLB should speed up perturbed runs on average, got {mean_speedup}"
    );

    let tables = fig5_flexibility(&cells);
    assert_eq!(tables.len(), 3, "three perturbation scenarios");
    for (without, with) in &tables {
        assert_eq!(without.rows.len(), 13);
        assert_eq!(with.rows.len(), 13);
    }
    let csv = perturb_to_csv(&cells);
    assert!(csv.starts_with("technique,scenario"));
}

#[test]
fn fig5_rdlb_boosts_adaptive_flexibility_under_combined() {
    // The paper's headline: AWF-* flexibility improves dramatically with
    // rDLB under combined perturbations. At smoke scale we assert the
    // direction: ρ_flex(with) ≤ ρ_flex(without) for the AWF family mean.
    let cells = fig3_perturbations(AppKind::Uniform, &smoke()).unwrap();
    let tables = fig5_flexibility(&cells);
    let combined = tables
        .iter()
        .find(|(w, _)| w.scenario.starts_with("combined"))
        .expect("combined scenario present");
    let awf_mean = |rows: &[rdlb::robustness::RobustnessRow]| {
        let v: Vec<f64> = rows
            .iter()
            .filter(|r| r.technique.starts_with("AWF"))
            .map(|r| if r.rho.is_finite() { r.rho } else { 1e6 })
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    let without = awf_mean(&combined.0.rows);
    let with = awf_mean(&combined.1.rows);
    assert!(
        with <= without * 1.5,
        "AWF flexibility should not degrade with rDLB: {with} vs {without}"
    );
}

#[test]
fn conceptual_traces_reproduce_figures_1_and_2() {
    use rdlb::experiments::{conceptual_trace, ConceptualScenario};
    // Fig. 1b: hang; Fig. 1c: completes with rescheduling.
    let (hang, _) = conceptual_trace(ConceptualScenario::Failure { rdlb: false }).unwrap();
    assert!(hang.hung);
    let (ok, trace) = conceptual_trace(ConceptualScenario::Failure { rdlb: true }).unwrap();
    assert!(ok.completed());
    assert!(trace.rescheduled().count() >= 1);
    assert!(trace.lost().count() >= 1);
    // Fig. 2: completes both ways, rDLB faster.
    let (slow, _) = conceptual_trace(ConceptualScenario::Perturbation { rdlb: false }).unwrap();
    let (fast, _) = conceptual_trace(ConceptualScenario::Perturbation { rdlb: true }).unwrap();
    assert!(slow.completed() && fast.completed());
    assert!(fast.parallel_time < slow.parallel_time);
}

#[test]
fn theory_validation_within_tolerance() {
    let rows = rdlb::experiments::theory_validation(12).unwrap();
    assert_eq!(rows.len(), 4);
    for (q, model, sim, err) in rows {
        assert!(err < 0.1, "q={q}: model {model} vs sim {sim} (err {err})");
    }
}
