//! Failure-injection edge cases (DESIGN.md §9): failures before the first
//! request, during the last chunk, simultaneous mass failures, failures of
//! PEs that only ever received rescheduled work, and perturbation windows
//! that open/close mid-run.

use std::sync::Arc;

use rdlb::apps::{AppKind, Workload};
use rdlb::dls::Technique;
use rdlb::sim::{FailurePlan, Perturbation, PerturbationModel, PerturbKind, SimCluster, SimParams, Topology};

fn base(n: usize, p: usize, technique: Technique, rdlb: bool) -> SimParams {
    SimParams::new(
        Workload::build(AppKind::Uniform, n, 1e-3, 7),
        Topology::flat(p),
        technique,
        rdlb,
    )
}

#[test]
fn failure_immediately_after_startup() {
    // A PE that dies at t=0+ε has already sent its initial request (MPI
    // ranks request at startup); the master unknowingly assigns it a chunk
    // which evaporates. Without rDLB that chunk hangs the run; with rDLB the
    // survivors re-execute it.
    let mk = |rdlb: bool| {
        let mut prm = base(500, 4, Technique::Fac, rdlb);
        prm.failures = Arc::new(FailurePlan::explicit(4, &[(3, 1e-9)]));
        SimCluster::new(prm).unwrap().run().unwrap()
    };
    assert!(mk(false).hung, "lost startup chunk must hang without rDLB");
    let o = mk(true);
    assert!(o.completed(), "{o:?}");
}

#[test]
fn failure_during_final_chunk() {
    // The last unfinished chunk's owner dies mid-compute: only rDLB saves it.
    let mk = |rdlb: bool| {
        let mut prm = base(100, 2, Technique::Gss, rdlb);
        // Worker 1 gets ~half the work; it dies early into its compute.
        prm.failures = Arc::new(FailurePlan::explicit(2, &[(1, 0.02)]));
        SimCluster::new(prm).unwrap().run().unwrap()
    };
    assert!(mk(false).hung);
    let o = mk(true);
    assert!(o.completed());
    assert_eq!(o.finished, 100);
}

#[test]
fn simultaneous_mass_failure() {
    // All non-master PEs die at the same instant.
    let p = 16;
    let pairs: Vec<(usize, f64)> = (1..p).map(|w| (w, 0.05)).collect();
    let mut prm = base(2000, p, Technique::Fac, true);
    prm.failures = Arc::new(FailurePlan::explicit(p, &pairs));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    assert_eq!(o.failures, p - 1);
}

#[test]
fn staggered_cascading_failures() {
    // PEs die one after another through the run; rDLB keeps absorbing.
    let p = 8;
    let pairs: Vec<(usize, f64)> = (1..p).map(|w| (w, 0.02 * w as f64)).collect();
    let mut prm = base(1500, p, Technique::AwfC, true);
    prm.failures = Arc::new(FailurePlan::explicit(p, &pairs));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
}

#[test]
fn ss_under_p_minus_1_failures_is_lossless_per_chunk() {
    // SS loses at most one iteration per failed PE (chunk size 1) — the
    // paper's minimal-lost-work argument.
    let p = 8;
    let mut prm = base(800, p, Technique::Ss, true);
    prm.failures = Arc::new(FailurePlan::random(p, p - 1, 0.05, 3));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.completed());
    // Duplicated work bounded by ~1 iteration per failure + tail overlap.
    assert!(
        o.stats.duplicate_iterations <= 4 * (p as u64 - 1) + 8,
        "SS duplicated too much: {}",
        o.stats.duplicate_iterations
    );
}

#[test]
fn windowed_perturbation_opens_and_closes() {
    // A slowdown window that ends mid-run: finish time must account for the
    // speed change (piecewise integration), and the run completes.
    let mut prm = base(3000, 4, Technique::Fac, true);
    prm.perturbations = Arc::new(PerturbationModel {
        perturbations: vec![Perturbation {
            kind: PerturbKind::PeSlowdown { node: 0, factor: 0.2 },
            start: 0.1,
            end: 0.3,
        }],
    });
    let o = SimCluster::new(prm.clone()).unwrap().run().unwrap();
    assert!(o.completed());
    // Must be slower than unperturbed but not 5x slower (window closes).
    let clean = {
        let mut c = prm.clone();
        c.perturbations = Arc::new(PerturbationModel::none());
        SimCluster::new(c).unwrap().run().unwrap()
    };
    assert!(o.parallel_time > clean.parallel_time);
    assert!(o.parallel_time < clean.parallel_time * 5.0);
}

#[test]
fn failures_and_perturbations_combined() {
    // Both at once: a slowed node AND failures elsewhere.
    let topo = Topology::new(4, 2);
    let mut prm = SimParams::new(
        Workload::build(AppKind::Exponential, 2000, 1e-3, 11),
        topo,
        Technique::Fac,
        true,
    );
    prm.failures = Arc::new(FailurePlan::explicit(8, &[(1, 0.05), (2, 0.08)]));
    prm.perturbations = Arc::new(PerturbationModel::combined(3, 0.25, 0.05));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    assert_eq!(o.finished, 2000);
}

#[test]
fn hang_detection_reports_partial_progress() {
    let mut prm = base(1000, 4, Technique::Tss, false);
    prm.failures = Arc::new(FailurePlan::explicit(4, &[(1, 0.01), (2, 0.012), (3, 0.014)]));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.hung);
    assert!(o.finished > 0, "some work must have completed before the hang");
    assert!(o.finished < 1000);
    assert!(o.parallel_time.is_infinite());
}

#[test]
fn zero_latency_zero_overhead_still_works() {
    let mut prm = base(500, 4, Technique::Gss, true);
    prm.base_latency = 0.0;
    prm.sched_overhead = 0.0;
    prm.failures = Arc::new(FailurePlan::explicit(4, &[(2, 0.01)]));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.completed());
}

#[test]
fn tiny_workload_more_pes_than_tasks() {
    let mut prm = base(3, 16, Technique::Fac, true);
    prm.failures = Arc::new(FailurePlan::random(16, 8, 0.001, 5));
    let o = SimCluster::new(prm).unwrap().run().unwrap();
    assert!(o.completed());
    assert_eq!(o.finished, 3);
}
