//! End-to-end tests of the chaos harness: a deterministic smoke campaign,
//! the oracle self-test (a deliberately injected coordinator bug must be
//! detected, shrunk to a minimal schedule, and replayable from its JSON
//! reproducer), and wire-chaos resilience.
//!
//! Every test arms a wall-clock watchdog so a deadlock fails in seconds
//! with a diagnostic instead of stalling CI to the job timeout.

use std::time::Duration;

use rdlb::chaos::{
    check_scenario, execute_scenario, expected_digest, run_chaos, scenario_from_json_str,
    scenario_to_json_string, shrink, BugHook, ChaosBudget, ChaosScenario, ChaosSettings,
    WireChaos,
};
use rdlb::config::RuntimeKind;
use rdlb::dls::Technique;
use rdlb::util::Watchdog;

/// A small campaign passes every invariant and is seed-deterministic in
/// all its reported counts.
#[test]
fn smoke_campaign_passes_and_repeats_identically() {
    let _wd = Watchdog::arm("chaos smoke campaign", Duration::from_secs(300));
    let settings = ChaosSettings::new(9, ChaosBudget { scenarios: 24 });
    let a = run_chaos(&settings).unwrap();
    let b = run_chaos(&settings).unwrap();
    assert!(a.passed(), "invariant violations in a clean build: {:?}", a.failures);
    assert_eq!(a.scenarios, 24);
    assert_eq!((a.scenarios, a.runs, a.checks), (b.scenarios, b.runs, b.checks));
    assert_eq!(a.summary(), b.summary(), "campaign output must be seed-deterministic");
    assert!(a.runs >= a.scenarios, "every scenario runs on >=1 runtime");
    assert!(a.checks > a.runs * 2, "multiple invariants per run");
}

/// The acceptance-criteria oracle self-test: a deliberately injected
/// coordinator bug (the test-only hook that drops one re-dispatch by
/// prematurely marking it Finished) is detected by the invariants and
/// shrunk to a minimal schedule whose JSON reproducer replays the failure
/// deterministically.
#[test]
fn injected_redispatch_drop_is_detected_shrunk_and_replayable() {
    let _wd = Watchdog::arm("chaos bug detection", Duration::from_secs(300));

    // A noisy schedule around the bug: one mid-chunk fail-stop forces a
    // re-dispatch (which the armed bug silently drops), plus perturbation
    // and wire noise the shrinker should strip.
    let mut sc = ChaosScenario::baseline(0, 11, 160, 4, Technique::Fac, true, 2e-4);
    sc.bug = Some(BugHook::DropOneRedispatch);
    sc.faults[3].fail_after = Some(sc.est_makespan() * 0.3);
    sc.faults[2].slowdown = 1.5;
    sc.faults[1].latency = 5e-4;
    sc.wire = WireChaos {
        drop_prob: 0.0,
        dup_prob: 0.05,
        delay_prob: 0.1,
        delay_ms: 0.3,
        ..WireChaos::quiet()
    };
    sc.validate().unwrap();

    // 1. Detection.
    let runs = execute_scenario(&sc).unwrap();
    assert_eq!(runs.len(), 1, "bug-armed schedules are net-only");
    let (checks, violations) = check_scenario(&sc, &runs);
    assert!(checks >= 4);
    assert!(
        violations.iter().any(|v| v.invariant == "exactly-once"),
        "the dropped re-dispatch must surface as an exactly-once violation: {violations:?}"
    );

    // 2. Shrinking strips the noise but keeps the failure.
    let shrunk = shrink(&sc, 48);
    assert!(!shrunk.violations.is_empty(), "shrunk schedule must still fail");
    assert!(shrunk.scenario.validate().is_ok());
    assert!(shrunk.scenario.wire.is_quiet(), "wire noise must shrink away");
    assert!(!shrunk.scenario.has_perturbations(), "perturbations must shrink away");
    assert!(shrunk.scenario.n <= sc.n && shrunk.scenario.p <= sc.p);

    // 3. The JSON reproducer round-trips exactly and replays the failure.
    let text = scenario_to_json_string(&shrunk.scenario);
    let back = scenario_from_json_str(&text).unwrap();
    assert_eq!(back, shrunk.scenario, "reproducer must deserialize to the identical schedule");
    let replayed = execute_scenario(&back).unwrap();
    let (_checks, again) = check_scenario(&back, &replayed);
    assert!(
        again.iter().any(|v| v.invariant == "exactly-once"),
        "replayed reproducer must reproduce the violation: {again:?}"
    );
}

/// Heavy frame chaos (drops, duplicates, delays) on top of a fail-stop:
/// with rDLB on, the run still completes with the exact serial digest —
/// the paper's no-detection robustness extends to a lossy interconnect.
#[test]
fn wire_chaos_with_failures_still_completes_exactly_once() {
    let _wd = Watchdog::arm("chaos wire resilience", Duration::from_secs(300));
    let mut sc = ChaosScenario::baseline(1, 23, 120, 4, Technique::Gss, true, 2e-4);
    sc.faults[2].fail_after = Some(sc.est_makespan() * 0.4);
    sc.wire = WireChaos {
        drop_prob: 0.15,
        dup_prob: 0.10,
        delay_prob: 0.15,
        delay_ms: 1.0,
        ..WireChaos::quiet()
    };
    let runs = execute_scenario(&sc).unwrap();
    assert_eq!(runs.len(), 1);
    let net = &runs[0];
    assert_eq!(net.runtime, RuntimeKind::Net);
    assert!(net.outcome.completed(), "{:?}", net.outcome);
    assert_eq!(net.outcome.result_digest, expected_digest(&sc));
    let (_checks, violations) = check_scenario(&sc, &runs);
    assert!(violations.is_empty(), "{violations:?}");
}

/// Late joiners and a stale-version churner: the master absorbs mid-run
/// registration, refuses the stale peer (visible in stats, never
/// scheduled), and still completes exactly once.
#[test]
fn late_join_and_churn_are_absorbed() {
    let _wd = Watchdog::arm("chaos churn", Duration::from_secs(300));
    // Workload sized so the run comfortably outlives both the late join and
    // the churner's registration.
    let mut sc = ChaosScenario::baseline(2, 31, 100, 4, Technique::Fac, true, 1e-3);
    sc.faults[1].join_after = sc.est_makespan() * 0.5;
    sc.faults[3].stale_version = true;
    let runs = execute_scenario(&sc).unwrap();
    let net = &runs[0];
    assert!(net.outcome.completed(), "{:?}", net.outcome);
    assert_eq!(net.outcome.stats.refused_workers, 1);
    assert_eq!(net.reports[3].chunks, 0, "refused churner must never be scheduled");
    assert_eq!(net.outcome.result_digest, expected_digest(&sc));
    let (_checks, violations) = check_scenario(&sc, &runs);
    assert!(violations.is_empty(), "{violations:?}");
}
