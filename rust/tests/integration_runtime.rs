//! Integration tests: PJRT runtime (AOT artifacts → rust execution) and the
//! full native-runtime-over-PJRT path. Skipped (with a notice) when
//! `artifacts/` has not been built yet (`make artifacts`).

use std::path::PathBuf;
use std::sync::Arc;

use rdlb::native::{ComputeBackend, NativeParams, NativeRuntime};
use rdlb::runtime::{ComputeRequest, ComputeService, PjrtEngine};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

#[test]
fn pjrt_mandelbrot_matches_native_exactly_on_grid() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let app = engine.mandelbrot_app();
    // A sizeable deterministic sample across the plane.
    let ids: Vec<u32> = (0..4096u32).map(|i| (i * 64) % app.n_tasks() as u32).collect();
    let got = engine.mandelbrot_chunk(&ids).unwrap();
    let want = app.compute_chunk(&ids);
    let mismatches = got.iter().zip(&want).filter(|(a, b)| a != b).count();
    // Same f32 semantics, but XLA fuses/reorders float ops differently from
    // rustc: pixels whose orbit grazes |z| == 2 can flip the escape test and
    // then diverge. Allow <1% such pixels (see python/tests/test_mandelbrot.py
    // for the same tolerance between two XLA graphs).
    assert!(mismatches * 100 <= ids.len(), "{mismatches}/{} mismatched", ids.len());
}

#[test]
fn pjrt_handles_ragged_and_padded_chunks() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let chunk = engine.manifest().mandelbrot.chunk;
    // Exactly one executable width, one more than a width, and a tiny tail.
    for len in [1usize, 7, chunk, chunk + 1, 2 * chunk + 3] {
        let ids: Vec<u32> = (0..len as u32).collect();
        let counts = engine.mandelbrot_chunk(&ids).unwrap();
        assert_eq!(counts.len(), len, "len {len}");
    }
}

#[test]
fn pjrt_psia_images_match_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let tasks: Vec<u32> = vec![0, 1, 999, 2047, 4000];
    let got = engine.psia_chunk(&tasks).unwrap();
    let want = engine.psia_app().compute_chunk(&tasks);
    for (k, (g, w)) in got.iter().zip(&want).enumerate() {
        let max_err = g
            .iter()
            .zip(w)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "task {k}: max err {max_err}");
    }
}

#[test]
fn compute_service_serves_concurrent_workers() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = ComputeService::spawn(dir).unwrap();
    let mut joins = Vec::new();
    for w in 0..4u32 {
        let handle = svc.handle();
        joins.push(std::thread::spawn(move || {
            let ids: Vec<u32> = (w * 100..w * 100 + 50).collect();
            let resp = handle.compute(ComputeRequest::Mandelbrot(ids)).unwrap();
            assert_eq!(resp.len(), 50);
            resp.digest()
        }));
    }
    let digests: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert!(digests.iter().all(|d| *d >= 0.0));
}

#[test]
fn native_runtime_over_pjrt_with_failures_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = ComputeService::spawn(dir).unwrap();
    let mut params = NativeParams::new(
        4096,
        4,
        rdlb::dls::Technique::Fac,
        true,
        ComputeBackend::PjrtMandelbrot(svc.handle()),
    );
    params = params.with_failures(2, 0.3);
    params.timeout = std::time::Duration::from_secs(120);
    let o = NativeRuntime::new(params).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    assert_eq!(o.finished, 4096);
}

#[test]
fn digest_is_failure_invariant() {
    // The summed result digest over first completions must not depend on
    // which workers failed — correctness of results under rDLB recovery.
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let app = Arc::new(engine.mandelbrot_app());
    drop(engine);

    let run = |failures: usize| {
        let mut p = NativeParams::new(
            1024,
            4,
            rdlb::dls::Technique::Gss,
            true,
            ComputeBackend::Mandelbrot(app.clone()),
        );
        if failures > 0 {
            p = p.with_failures(failures, 0.05);
        }
        NativeRuntime::new(p).unwrap().run().unwrap()
    };
    let clean = run(0);
    let failed = run(3);
    assert!(clean.completed() && failed.completed());
    assert_eq!(clean.finished, failed.finished);
}
