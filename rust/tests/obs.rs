//! Integration tests of the observability layer (`rdlb::obs`): journal
//! codec round-trips under randomized event streams, histogram percentiles
//! bounded against an exact sorted model, byte-identical journals for
//! seeded simulator runs, and the journal replay oracle on failure-heavy
//! runs of the wall-clock runtimes.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use rdlb::apps::{AppKind, CostModel};
use rdlb::config::{ExperimentConfig, Scenario};
use rdlb::coordinator::{
    Assignment, Effect, EngineEvent, EventSink, ResultNotes, SharedSink, TaskSet,
};
use rdlb::dls::Technique;
use rdlb::hier::{HierParams, HierRuntime};
use rdlb::native::{ComputeBackend, NativeParams, NativeRuntime};
use rdlb::net::{run_loopback, NetMasterParams};
use rdlb::obs::{
    read_journal, replay_stats, replay_trace, Histogram, JournalEvent, JournalRecord, JournalSink,
    MetricsRegistry, MetricsSink,
};
use rdlb::sim::{Outcome, SimCluster};
use rdlb::util::{Rng, Watchdog};

fn synthetic(n: usize, cost: f64) -> ComputeBackend {
    ComputeBackend::Synthetic {
        model: Arc::new(CostModel::from_costs(vec![cost; n])),
        scale: 1.0,
    }
}

// ---------------------------------------------------------------------------
// Journal codec: randomized round-trip property
// ---------------------------------------------------------------------------

fn rand_task_set(rng: &mut Rng) -> TaskSet {
    if rng.next_f64() < 0.5 {
        let start = rng.gen_range(0, 100_000) as u32;
        TaskSet::Range { start, end: start + rng.gen_range(0, 512) as u32 }
    } else {
        let count = rng.gen_range(0, 24) as usize;
        TaskSet::List((0..count).map(|_| rng.gen_range(0, 1 << 20) as u32).collect())
    }
}

fn rand_effect(rng: &mut Rng) -> Effect {
    match rng.gen_range(0, 4) {
        0 => Effect::Assign(Assignment {
            id: rng.next_u64() >> 1,
            worker: rng.gen_range(0, 255) as usize,
            tasks: rand_task_set(rng),
            rescheduled: rng.next_f64() < 0.3,
        }),
        1 => Effect::Park { worker: rng.gen_range(0, 255) as usize },
        2 => Effect::Wake { worker: rng.gen_range(0, 255) as usize },
        3 => Effect::TerminateWorker { worker: rng.gen_range(0, 255) as usize },
        _ => Effect::Completed,
    }
}

/// Feed hundreds of randomized `(scope, now, event, effects, notes)` tuples
/// through a [`JournalSink`] and demand the decoder returns them exactly —
/// every event kind, effect kind and task-set shape, in order.
#[test]
fn journal_round_trips_random_event_streams() {
    let mut rng = Rng::new(0x0B5E_2026);
    for _trial in 0..8 {
        let mut sink = JournalSink::new();
        let mut expected: Vec<JournalRecord> = Vec::new();
        for _ in 0..rng.gen_range(1, 120) {
            let scope = rng.gen_range(0, 5) as u32;
            let now = rng.uniform(0.0, 1e4);
            let effects: Vec<Effect> =
                (0..rng.gen_range(0, 4)).map(|_| rand_effect(&mut rng)).collect();
            let (event, notes) = match rng.gen_range(0, 4) {
                0 => (JournalEvent::Request { worker: rng.gen_range(0, 255) as usize }, None),
                1 => {
                    let notes = ResultNotes {
                        completed_chunks: rng.gen_range(0, 1),
                        rescheduled_completions: rng.gen_range(0, 1),
                        unknown_results: rng.gen_range(0, 1),
                        first_completions: rng.gen_range(0, 1 << 20),
                        duplicate_iterations: rng.gen_range(0, 1 << 20),
                        digest_delta: rng.uniform(-10.0, 1e6),
                    };
                    (
                        JournalEvent::Result {
                            worker: rng.gen_range(0, 255) as usize,
                            assignment_id: rng.next_u64() >> 1,
                            compute_secs: rng.uniform(0.0, 60.0),
                            digest_count: rng.gen_range(0, 4096) as u32,
                        },
                        Some(notes),
                    )
                }
                2 => (JournalEvent::Disconnected { worker: rng.gen_range(0, 255) as usize }, None),
                3 => (JournalEvent::Refused { worker: rng.gen_range(0, 255) as usize }, None),
                _ => (JournalEvent::Timeout, None),
            };
            // Mirror the record through the sink's EventSink interface.
            let notes = notes.unwrap_or_default();
            let digests;
            let engine_event = match &event {
                JournalEvent::Request { worker } => EngineEvent::WorkerRequest { worker: *worker },
                JournalEvent::Result { worker, assignment_id, compute_secs, digest_count } => {
                    digests = vec![0.0; *digest_count as usize];
                    EngineEvent::ResultReceived {
                        worker: *worker,
                        assignment_id: *assignment_id,
                        compute_secs: *compute_secs,
                        digests: &digests,
                    }
                }
                JournalEvent::Disconnected { worker } => {
                    EngineEvent::WorkerDisconnected { worker: *worker }
                }
                JournalEvent::Refused { worker } => EngineEvent::VersionRefused { worker: *worker },
                JournalEvent::Timeout => EngineEvent::Timeout,
            };
            sink.record(scope, now, &engine_event, &effects, &notes);
            expected.push(JournalRecord { scope, now, event, notes, effects });
        }
        let decoded = read_journal(sink.bytes()).unwrap();
        assert_eq!(decoded, expected);
    }
}

// ---------------------------------------------------------------------------
// Histogram percentiles vs an exact sorted model
// ---------------------------------------------------------------------------

/// The log-linear histogram's percentile is an upper-bound estimate with a
/// one-sub-bucket error: for every quantile it must bracket the exact
/// order statistic within `[exact, exact × (1 + 1/SUBS)]` (SUBS = 8).
#[test]
fn histogram_percentiles_bound_the_exact_sorted_model() {
    let mut rng = Rng::new(7);
    for _trial in 0..20 {
        let n = rng.gen_range(1, 400) as usize;
        let mut samples: Vec<f64> =
            (0..n).map(|_| 10f64.powf(rng.uniform(-6.0, 2.0))).collect();
        let mut h = Histogram::new();
        for &s in &samples {
            h.record(s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let estimate = h.percentile(q);
            let rank = (((q * n as f64).ceil() as usize).max(1) - 1).min(n - 1);
            let exact = samples[rank];
            assert!(
                estimate >= exact * (1.0 - 1e-12),
                "p{q}: estimate {estimate} below exact {exact} (n={n})"
            );
            assert!(
                estimate <= exact * 1.125 * (1.0 + 1e-12),
                "p{q}: estimate {estimate} beyond one bucket above exact {exact} (n={n})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Seeded simulator: byte-identical journals, passive sinks
// ---------------------------------------------------------------------------

fn sim_params(seed: u64) -> rdlb::sim::SimParams {
    ExperimentConfig::builder()
        .app(AppKind::Uniform)
        .tasks(600)
        .pes(8)
        .technique(Technique::Fac)
        .rdlb(true)
        .scenario(Scenario::failures(3))
        .mean_cost(1e-3)
        .seed(seed)
        .build()
        .unwrap()
        .sim_params(0)
        .unwrap()
}

fn journaled_sim_run(seed: u64) -> (Outcome, Vec<u8>) {
    let sink = Arc::new(Mutex::new(JournalSink::new()));
    let mut params = sim_params(seed);
    params.sink = Some(SharedSink::from_arc(sink.clone()));
    let outcome = SimCluster::new(params).unwrap().run().unwrap();
    let bytes = sink.lock().unwrap().bytes().to_vec();
    (outcome, bytes)
}

#[test]
fn seeded_sim_journal_is_byte_identical_and_the_sink_is_passive() {
    let (a, journal_a) = journaled_sim_run(1);
    let (b, journal_b) = journaled_sim_run(1);
    assert!(journal_a.len() > 10, "journal must contain records, not just the header");
    assert_eq!(journal_a, journal_b, "same seed must produce a byte-identical journal");
    assert_eq!(a.stats, b.stats);

    // Passivity: a run with no sink installed is identical.
    let bare = SimCluster::new(sim_params(1)).unwrap().run().unwrap();
    assert_eq!(a.parallel_time, bare.parallel_time);
    assert_eq!(a.finished, bare.finished);
    assert_eq!(a.stats, bare.stats);

    // Different seeds produce different histories.
    let (_, journal_c) = journaled_sim_run(2);
    assert_ne!(journal_a, journal_c);

    // The replay oracle holds on the simulator too.
    let records = read_journal(&journal_a).unwrap();
    assert_eq!(replay_stats(&records), a.stats);
}

// ---------------------------------------------------------------------------
// Journal replay oracle on the wall-clock runtimes
// ---------------------------------------------------------------------------

/// The paper's P−1-failure scenario over the loopback wire protocol, with
/// the journal tap armed: replaying the journal must reproduce the live
/// `MasterStats` exactly, and the reconstructed trace must show the rDLB
/// re-dispatch that completed the run.
#[test]
fn journal_replay_matches_live_stats_under_p_minus_1_failures() {
    let _wd = Watchdog::arm(
        "journal_replay_matches_live_stats_under_p_minus_1_failures",
        Duration::from_secs(180),
    );
    let n = 600;
    let sink = Arc::new(Mutex::new(JournalSink::new()));
    let mut params =
        NetMasterParams::new(n, 4, Technique::Fac, true).with_failures(3, 0.12).unwrap();
    params.timeout = Duration::from_secs(60);
    params.sink = Some(SharedSink::from_arc(sink.clone()));

    let (outcome, _reports) = run_loopback(params, &synthetic(n, 1e-3)).unwrap();
    assert!(outcome.completed(), "rDLB must absorb P-1 failures: {outcome:?}");
    assert_eq!(outcome.failures, 3);

    let bytes = sink.lock().unwrap().bytes().to_vec();
    let records = read_journal(&bytes).unwrap();
    assert_eq!(replay_stats(&records), outcome.stats, "journal replay == live counters");

    let trace = replay_trace(&records);
    assert!(!trace.is_empty());
    assert!(trace.rescheduled().count() > 0, "recovery must appear as rescheduled chunks");
    assert!(trace.lost().count() > 0, "failed workers' in-flight chunks must appear lost");
}

/// The hierarchical runtime journals the root engine at scope 0 and each
/// group's inner engine at scope 1+g into the same sink; the scope-0
/// replay must equal the outcome's (root-engine) stats.
#[test]
fn hier_journal_replays_root_stats_from_scope_zero() {
    let _wd =
        Watchdog::arm("hier_journal_replays_root_stats_from_scope_zero", Duration::from_secs(180));
    let n = 400;
    let sink = Arc::new(Mutex::new(JournalSink::new()));
    let mut params = HierParams::new(n, 2, 2, Technique::Fac, true, synthetic(n, 1e-4));
    params.sink = Some(SharedSink::from_arc(sink.clone()));

    let outcome = HierRuntime::new(params).unwrap().run().unwrap();
    assert!(outcome.completed(), "{outcome:?}");

    let bytes = sink.lock().unwrap().bytes().to_vec();
    let records = read_journal(&bytes).unwrap();
    assert!(records.iter().any(|r| r.scope == 0), "root engine records at scope 0");
    assert!(records.iter().any(|r| r.scope >= 1), "inner engines record at scope 1+g");
    assert_eq!(replay_stats(&records), outcome.stats, "scope-0 replay == root stats");
}

/// The metrics sink fills the registry from a real native run, and its
/// counters agree with the outcome's.
#[test]
fn metrics_sink_populates_registry_on_a_native_run() {
    let _wd =
        Watchdog::arm("metrics_sink_populates_registry_on_a_native_run", Duration::from_secs(120));
    let n = 400;
    let registry = Arc::new(Mutex::new(MetricsRegistry::new()));
    let mut params = NativeParams::new(n, 4, Technique::Fac, true, synthetic(n, 1e-4));
    params.sink = Some(SharedSink::new(MetricsSink::new(registry.clone())));

    let outcome = NativeRuntime::new(params).unwrap().run().unwrap();
    assert!(outcome.completed(), "{outcome:?}");

    let reg = registry.lock().unwrap();
    assert!(!reg.is_empty());
    assert_eq!(reg.counter("rdlb_results_total"), outcome.stats.completed_chunks);
    assert_eq!(reg.counter("rdlb_assigned_chunks_total"), outcome.stats.assigned_chunks);
    assert!(reg.counter("rdlb_events_total") > 0);
    let compute = reg.histogram("rdlb_chunk_compute_seconds").unwrap();
    assert_eq!(compute.count(), outcome.stats.completed_chunks);
    let text = reg.to_prometheus();
    assert!(text.contains("# TYPE rdlb_events_total counter"), "{text}");
    assert!(text.contains("rdlb_chunk_compute_seconds_bucket"), "{text}");
}
