//! Property tests for the wire-protocol codec: randomized frame generation
//! over the in-tree PRNG (proptest is unavailable offline), asserting
//! encode/decode round-trips, stream framing, and graceful rejection of
//! corrupted bytes — the decoder must error, never panic.

use std::io::Cursor;

use rdlb::coordinator::TaskSet;
use rdlb::net::protocol::{read_frame, write_frame};
use rdlb::net::{FaultSpec, Frame, Welcome, WireAssignment, WorkResult, WorkerHello};
use rdlb::util::Rng;

fn rand_string(rng: &mut Rng, max: usize) -> String {
    let len = (rng.next_u64() as usize) % (max + 1);
    (0..len).map(|_| char::from(b'a' + (rng.next_u64() % 26) as u8)).collect()
}

/// Random v2 task set: contiguous ranges (possibly empty, possibly pressed
/// against the u32 boundary) and arbitrary explicit lists.
fn rand_task_set(rng: &mut Rng) -> TaskSet {
    match rng.next_u64() % 4 {
        0 => {
            // Range anywhere, length 0..1000.
            let start = (rng.next_u64() % (u32::MAX as u64 - 1000)) as u32;
            let len = (rng.next_u64() % 1000) as u32;
            TaskSet::Range { start, end: start + len }
        }
        1 => {
            // Range ending exactly at the u32 boundary.
            let len = (rng.next_u64() % 64) as u32;
            TaskSet::Range { start: u32::MAX - len, end: u32::MAX }
        }
        _ => {
            let len = (rng.next_u64() % 200) as usize;
            TaskSet::List((0..len).map(|_| rng.next_u64() as u32).collect())
        }
    }
}

fn rand_frame(rng: &mut Rng) -> Frame {
    match rng.next_u64() % 9 {
        0 => Frame::Hello(WorkerHello {
            version: rng.next_u64() as u16,
            backend: rand_string(rng, 32),
        }),
        1 => Frame::Welcome(Welcome {
            worker: rng.next_u64() as u32,
            n: rng.next_u64() % (1 << 48),
            epoch: rng.next_u64() as u32,
            ping: rng.next_f64() < 0.5,
            fault: FaultSpec {
                fail_after: if rng.next_f64() < 0.5 { Some(rng.next_f64() * 100.0) } else { None },
                slowdown: 1.0 + rng.next_f64() * 4.0,
                latency: rng.next_f64(),
                stall_after: if rng.next_f64() < 0.5 { Some(rng.next_f64() * 100.0) } else { None },
                stall_secs: rng.next_f64() * 10.0,
            },
        }),
        2 => Frame::Request { worker: rng.next_u64() as u32 },
        3 => Frame::Assign(WireAssignment {
            id: rng.next_u64(),
            worker: rng.next_u64() as u32,
            rescheduled: rng.next_f64() < 0.5,
            tasks: rand_task_set(rng),
        }),
        4 => Frame::Wait,
        5 => {
            let len = (rng.next_u64() % 200) as usize;
            Frame::Result(WorkResult {
                worker: rng.next_u64() as u32,
                assignment: rng.next_u64(),
                epoch: rng.next_u64() as u32,
                compute_secs: rng.next_f64() * 10.0,
                digests: (0..len).map(|_| (rng.next_f64() - 0.5) * 1e6).collect(),
            })
        }
        6 => Frame::Ping,
        7 => Frame::Pong { worker: rng.next_u64() as u32, progress: rng.next_u64() },
        _ => Frame::Terminate,
    }
}

#[test]
fn task_set_boundary_cases_roundtrip() {
    let assign = |tasks: TaskSet| {
        Frame::Assign(WireAssignment { id: u64::MAX, worker: u32::MAX, rescheduled: true, tasks })
    };
    let cases = [
        TaskSet::Range { start: 0, end: 0 },
        TaskSet::Range { start: u32::MAX, end: u32::MAX },
        TaskSet::Range { start: 0, end: u32::MAX },
        TaskSet::Range { start: u32::MAX - 1, end: u32::MAX },
        TaskSet::List(Vec::new()),
        TaskSet::List(vec![0]),
        TaskSet::List(vec![0, u32::MAX]),
        TaskSet::List(vec![u32::MAX - 2, u32::MAX - 1, u32::MAX]),
    ];
    for tasks in cases {
        let frame = assign(tasks);
        let back = Frame::decode(&frame.encode()).unwrap();
        assert_eq!(back, frame);
    }
}

#[test]
fn range_assign_payload_size_is_independent_of_length() {
    let encode_len = |start: u32, end: u32| {
        Frame::Assign(WireAssignment {
            id: 9,
            worker: 1,
            rescheduled: false,
            tasks: TaskSet::Range { start, end },
        })
        .encode()
        .len()
    };
    let sizes = [
        encode_len(0, 0),
        encode_len(0, 1),
        encode_len(0, 262_144),
        encode_len(u32::MAX - 1, u32::MAX),
    ];
    assert!(sizes.iter().all(|&s| s == sizes[0]), "{sizes:?}");
}

#[test]
fn random_frames_roundtrip() {
    let mut rng = Rng::new(0xF4A3E);
    for i in 0..500 {
        let frame = rand_frame(&mut rng);
        let bytes = frame.encode();
        let back = Frame::decode(&bytes).unwrap_or_else(|e| panic!("case {i}: {e:?}"));
        assert_eq!(back, frame, "case {i}");
    }
}

#[test]
fn random_frame_streams_roundtrip_through_length_prefixing() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..20 {
        let frames: Vec<Frame> = (0..50).map(|_| rand_frame(&mut rng)).collect();
        let mut wire = Vec::new();
        for f in &frames {
            write_frame(&mut wire, f).unwrap();
        }
        let mut cursor = Cursor::new(wire);
        for f in &frames {
            assert_eq!(&read_frame(&mut cursor).unwrap(), f);
        }
        assert!(read_frame(&mut cursor).is_err(), "clean EOF must be an error, not a frame");
    }
}

#[test]
fn every_strict_prefix_is_rejected() {
    let mut rng = Rng::new(0x7E57);
    for _ in 0..100 {
        let frame = rand_frame(&mut rng);
        let bytes = frame.encode();
        for cut in 0..bytes.len() {
            assert!(
                Frame::decode(&bytes[..cut]).is_err(),
                "prefix of {} bytes of a {}-byte {} frame must not decode",
                cut,
                bytes.len(),
                frame.label()
            );
        }
    }
}

#[test]
fn random_mutations_never_panic() {
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..300 {
        let frame = rand_frame(&mut rng);
        let mut bytes = frame.encode();
        let pos = (rng.next_u64() as usize) % bytes.len();
        bytes[pos] ^= (rng.next_u64() % 255 + 1) as u8;
        // A flipped byte may still decode to some other valid frame; the
        // property is that decoding never panics and trailing bytes or
        // truncated fields are reported as errors.
        let _ = Frame::decode(&bytes);
    }
}

#[test]
fn random_byte_soup_never_panics() {
    let mut rng = Rng::new(0x50FA);
    for _ in 0..300 {
        let len = (rng.next_u64() % 64) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = Frame::decode(&bytes);
    }
}
