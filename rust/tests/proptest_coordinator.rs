//! Property-based tests of the coordinator invariants (seeded randomized
//! cases over the in-tree PRNG — proptest is unavailable offline, so each
//! property is checked across a few hundred generated cases and failures
//! print the offending seed).
//!
//! Invariants (DESIGN.md §9):
//!  * conservation — every task is eventually Finished exactly once in the
//!    table, regardless of failures, as long as ≥1 live PE exists (rDLB on);
//!  * no phantom tasks — assignments only contain ids < N, ascending;
//!  * idempotence — duplicate completions never double-count;
//!  * holder exclusion — rDLB never re-assigns a task to a worker that
//!    currently holds it;
//!  * hang — with rDLB off, a lost chunk implies the run cannot complete.

use rdlb::coordinator::{HealthPolicy, Master, MasterConfig, Reply};
use rdlb::dls::{Technique, TechniqueParams};
use rdlb::util::Rng;

/// Drive a master with a randomized schedule of worker requests, losing
/// chunks assigned to "dead" workers. Returns whether the run completed.
fn drive(
    master: &mut Master,
    p: usize,
    fail_after: &[Option<usize>], // worker dies after k-th interaction
    rng: &mut Rng,
    max_steps: usize,
) -> bool {
    let mut interactions = vec![0usize; p];
    let mut pending: Vec<(usize, rdlb::coordinator::Assignment)> = Vec::new();
    for step in 0..max_steps {
        if master.is_complete() {
            return true;
        }
        let t = step as f64;
        let do_complete = !pending.is_empty() && rng.next_f64() < 0.5;
        if do_complete {
            let idx = rng.gen_range(0, (pending.len() - 1) as u64) as usize;
            let (w, a) = pending.swap_remove(idx);
            master.on_result(w, a.id, 0.01 * a.len() as f64, t);
            continue;
        }
        let w = rng.gen_range(0, (p - 1) as u64) as usize;
        let dead = fail_after[w].is_some_and(|k| interactions[w] >= k);
        if dead {
            continue;
        }
        interactions[w] += 1;
        match master.on_request(w, t) {
            Reply::Assign(a) => {
                let ids = a.tasks.to_vec();
                assert!(ids.windows(2).all(|x| x[0] < x[1]), "assignment not ascending");
                assert!(
                    ids.iter().all(|&id| (id as usize) < master.config().n),
                    "phantom task id"
                );
                let dies_now = fail_after[w].is_some_and(|k| interactions[w] >= k);
                if !dies_now {
                    pending.push((w, a));
                } // else: chunk lost
            }
            Reply::Wait | Reply::Terminate => {}
        }
    }
    // Flush everything still pending (live workers finish their chunks).
    while let Some((w, a)) = pending.pop() {
        master.on_result(w, a.id, 0.01, max_steps as f64);
    }
    // Final rounds of requests from live workers drain the pool.
    let mut guard = 0;
    loop {
        if master.is_complete() {
            return true;
        }
        let mut progressed = false;
        for w in 0..p {
            if fail_after[w].is_some() {
                continue;
            }
            if let Reply::Assign(a) = master.on_request(w, guard as f64 + 1e6) {
                master.on_result(w, a.id, 0.01, guard as f64 + 1e6);
                progressed = true;
            }
        }
        guard += 1;
        if !progressed || guard > 100_000 {
            return master.is_complete();
        }
    }
}

fn technique_menu() -> [Technique; 6] {
    [Technique::Ss, Technique::Gss, Technique::Fac, Technique::Tss, Technique::AwfC, Technique::Af]
}

#[test]
fn prop_conservation_under_random_failures_with_rdlb() {
    for seed in 0..120u64 {
        let mut rng = Rng::new(seed);
        let n = 20 + (rng.next_u64() % 400) as usize;
        let p = 2 + (rng.next_u64() % 12) as usize;
        let technique = technique_menu()[(rng.next_u64() % 6) as usize];
        // Random subset of workers (never 0) dies after a random number of
        // interactions.
        let fail_after: Vec<Option<usize>> = (0..p)
            .map(|w| (w != 0 && rng.next_f64() < 0.4).then(|| (rng.next_u64() % 5) as usize))
            .collect();
        let mut master = Master::new(MasterConfig {
            n,
            p,
            technique,
            params: TechniqueParams::default(),
            rdlb: true,
            health: HealthPolicy::default(),
        });
        let completed = drive(&mut master, p, &fail_after, &mut rng, 20 * n);
        assert!(completed, "seed {seed}: did not complete ({technique}, n={n}, p={p})");
        assert_eq!(master.table().finished_count(), n, "seed {seed}: task lost");
        let s = master.stats();
        assert_eq!(s.finished_iterations as usize, n, "seed {seed}");
        assert!(s.finished_iterations + s.duplicate_iterations >= n as u64);
    }
}

#[test]
fn prop_no_completion_without_rdlb_after_loss() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let n = 20 + (rng.next_u64() % 200) as usize;
        let p = 3 + (rng.next_u64() % 6) as usize;
        let technique = technique_menu()[(rng.next_u64() % 6) as usize];
        // Exactly one worker dies right after its first assignment. Issue
        // that first assignment explicitly so a chunk is guaranteed lost
        // (a late-requesting victim could otherwise receive Wait and lose
        // nothing).
        let victim = 1 + (rng.next_u64() % (p as u64 - 1)) as usize;
        let fail_after: Vec<Option<usize>> = (0..p).map(|w| (w == victim).then_some(0)).collect();
        let mut master = Master::new(MasterConfig {
            n,
            p,
            technique,
            params: TechniqueParams::default(),
            rdlb: false,
            health: HealthPolicy::default(),
        });
        match master.on_request(victim, 0.0) {
            Reply::Assign(_lost) => {} // evaporates with the victim
            other => panic!("first request must assign, got {other:?}"),
        }
        let completed = drive(&mut master, p, &fail_after, &mut rng, 20 * n);
        assert!(
            !completed,
            "seed {seed}: completed without rDLB despite a lost chunk ({technique})"
        );
        assert!(master.table().finished_count() < n);
    }
}

#[test]
fn prop_duplicate_results_never_double_count() {
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed ^ 0xD0D0);
        let n = 10 + (rng.next_u64() % 100) as usize;
        let p = 2 + (rng.next_u64() % 6) as usize;
        let mut master = Master::new(MasterConfig {
            n,
            p,
            technique: Technique::Fac,
            params: TechniqueParams::default(),
            rdlb: true,
            health: HealthPolicy::default(),
        });
        let mut assignments = Vec::new();
        let mut t = 0.0;
        while !master.is_complete() {
            let w = rng.gen_range(0, (p - 1) as u64) as usize;
            if let Reply::Assign(a) = master.on_request(w, t) {
                master.on_result(w, a.id, 0.01, t + 0.01);
                assignments.push((w, a));
            }
            t += 1.0;
            assert!(t < 1e6, "seed {seed}: stuck");
        }
        let finished_before = master.stats().finished_iterations;
        // Replay a random subset of results a second time.
        for (w, a) in &assignments {
            if rng.next_f64() < 0.3 {
                master.on_result(*w, a.id, 0.01, t);
            }
        }
        assert_eq!(master.stats().finished_iterations, finished_before, "seed {seed}");
        assert_eq!(master.table().finished_count(), n, "seed {seed}");
    }
}

#[test]
fn prop_holder_exclusion() {
    // A worker that holds the only pending tasks gets Wait, never a
    // duplicate of its own chunk.
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xACE);
        let n = 2 + (rng.next_u64() % 30) as usize;
        let p = 2;
        let mut master = Master::new(MasterConfig {
            n,
            p,
            technique: Technique::Gss,
            params: TechniqueParams::default(),
            rdlb: true,
            health: HealthPolicy::default(),
        });
        // Worker 1 grabs everything.
        let mut held: Vec<rdlb::coordinator::Assignment> = Vec::new();
        loop {
            match master.on_request(1, 0.0) {
                Reply::Assign(a) => held.push(a),
                Reply::Wait | Reply::Terminate => break,
            }
            assert!(held.len() <= 10 * n, "seed {seed}: runaway");
        }
        let held_ids: std::collections::HashSet<u32> =
            held.iter().flat_map(|a| a.tasks.iter()).collect();
        assert_eq!(held_ids.len(), n, "worker 1 should hold all tasks");
        assert_eq!(master.on_request(1, 1.0), Reply::Wait, "seed {seed}");
        // Worker 0 may duplicate them.
        match master.on_request(0, 1.0) {
            Reply::Assign(a) => assert!(a.rescheduled),
            other => panic!("seed {seed}: worker 0 got {other:?}"),
        }
    }
}

#[test]
fn prop_counts_partition_n() {
    // At every point of a random run: unscheduled + scheduled + finished == N.
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x7A57);
        let n = 50 + (rng.next_u64() % 200) as usize;
        let p = 4;
        let mut master = Master::new(MasterConfig {
            n,
            p,
            technique: Technique::Tss,
            params: TechniqueParams::default(),
            rdlb: true,
            health: HealthPolicy::default(),
        });
        let mut pending: Vec<(usize, rdlb::coordinator::Assignment)> = Vec::new();
        for step in 0..10 * n {
            let t = master.table();
            assert_eq!(
                t.unscheduled_count() + t.scheduled_count() + t.finished_count(),
                n,
                "seed {seed} step {step}"
            );
            if master.is_complete() {
                break;
            }
            let w = rng.gen_range(0, (p - 1) as u64) as usize;
            if !pending.is_empty() && rng.next_f64() < 0.6 {
                let (w2, a) = pending.pop().unwrap();
                master.on_result(w2, a.id, 0.01, step as f64);
            } else if let Reply::Assign(a) = master.on_request(w, step as f64) {
                pending.push((w, a));
            }
        }
    }
}
