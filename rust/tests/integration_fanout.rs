//! Fan-out tests of the readiness-loop net master: hundreds of loopback
//! workers against the single-threaded poll loop, event-driven accept with
//! late joiners, signal-latency bounds, and the opaque-transport bridge.
//!
//! Every test that blocks on threads or sockets arms a [`Watchdog`], so a
//! deadlocked run fails with a diagnostic instead of stalling `cargo test`.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use rdlb::apps::{CostModel, MandelbrotApp};
use rdlb::coordinator::{Engine, HealthPolicy, MasterConfig};
use rdlb::dls::Technique;
use rdlb::native::{ComputeBackend, NativeParams, NativeRuntime};
use rdlb::net::{
    run_loopback, run_worker, serve_tcp, FaultInjectingTransport, LoopbackTransport,
    NetMaster, NetMasterParams, TcpTransport, Transport, WireFaultPlan,
};
use rdlb::util::Watchdog;

fn synthetic(n: usize, cost: f64) -> ComputeBackend {
    ComputeBackend::Synthetic {
        model: Arc::new(CostModel::from_costs(vec![cost; n])),
        scale: 1.0,
    }
}

/// `Threads:` from /proc/self/status — the whole test process.
fn current_threads() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("proc status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line")
}

/// P = 256 loopback workers against one master thread: the digest must
/// match a serial (P = 1) run of the identical kernel bit-for-bit, and the
/// master must add O(1) threads — not one reader thread per connection.
#[test]
fn fanout_256_digest_parity_with_serial_kernel() {
    let _wd = Watchdog::arm("fanout_256_digest_parity_with_serial_kernel", Duration::from_secs(240));
    let app = MandelbrotApp { width: 32, height: 32, max_iter: 64, ..Default::default() };
    let n = app.n_tasks();
    let backend = ComputeBackend::Mandelbrot(Arc::new(app));

    // Serial reference: the same kernel, one worker, no wire protocol.
    let serial = NativeRuntime::new(NativeParams::new(n, 1, Technique::Fac, true, backend.clone()))
        .unwrap()
        .run()
        .unwrap();
    assert!(serial.completed(), "{serial:?}");

    let p = 256;
    let base_threads = current_threads();
    let peak = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let watcher = {
        let (peak, stop) = (peak.clone(), stop.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                peak.fetch_max(current_threads(), Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    let (net, reports) =
        run_loopback(NetMasterParams::new(n, p, Technique::Fac, true), &backend).unwrap();
    stop.store(true, Ordering::Relaxed);
    watcher.join().unwrap();

    assert!(net.completed(), "{net:?}");
    assert_eq!(net.finished, n);
    assert_eq!(reports.len(), p);
    // Escape-count digests are integer-valued: sums are exact, so a P=256
    // schedule must reproduce the serial digest bit-for-bit.
    assert_eq!(net.result_digest, serial.result_digest, "digest parity vs serial kernel");

    // One thread per worker plus a constant for master + harness.  The old
    // reader-thread master would add ~P more and trip this bound.
    let peak = peak.load(Ordering::Relaxed);
    assert!(
        peak <= base_threads + p + 40,
        "master thread count must be O(1) in P: peak {peak}, baseline {base_threads}, P {p}"
    );
}

/// The paper's headline scenario at fan-out scale: P−1 = 255 of 256
/// workers fail-stop and rDLB still finishes every iteration.
#[test]
fn fanout_256_completes_under_255_failures() {
    let _wd = Watchdog::arm("fanout_256_completes_under_255_failures", Duration::from_secs(300));
    let n = 600;
    let p = 256;
    let mut params =
        NetMasterParams::new(n, p, Technique::Fac, true).with_failures(p - 1, 0.4).unwrap();
    params.timeout = Duration::from_secs(120);
    let (outcome, reports) = run_loopback(params, &synthetic(n, 2e-3)).unwrap();
    assert!(outcome.completed(), "rDLB must absorb P-1 failures at P=256: {outcome:?}");
    assert_eq!(outcome.finished, n);
    assert_eq!(outcome.failures, p - 1);
    assert_eq!(reports.iter().filter(|r| r.failed).count(), p - 1);
    assert!(outcome.stats.rescheduled_chunks > 0, "recovery must go through re-dispatch");
}

/// Accept is event-driven: a worker connecting well after the others (and
/// after the master has already started dispatching nothing is required to
/// sleep-poll for it) registers mid-window and computes real work.
#[test]
fn late_joiner_registers_and_computes() {
    let _wd = Watchdog::arm("late_joiner_registers_and_computes", Duration::from_secs(120));
    let n = 600;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut params = NetMasterParams::new(n, 4, Technique::Fac, true);
    params.timeout = Duration::from_secs(60);

    let server = std::thread::spawn(move || serve_tcp(listener, params, Duration::from_secs(10)));
    let workers: Vec<_> = (0..4)
        .map(|w| {
            let backend = synthetic(n, 2e-3);
            let addr = addr.to_string();
            std::thread::spawn(move || {
                if w == 3 {
                    // The straggler: everyone else is already computing.
                    std::thread::sleep(Duration::from_millis(300));
                }
                let transport = TcpTransport::connect(&addr).unwrap();
                run_worker(Box::new(transport), backend, "late-joiner")
            })
        })
        .collect();

    let outcome = server.join().unwrap().unwrap();
    assert!(outcome.completed(), "{outcome:?}");
    assert_eq!(outcome.finished, n);
    let reports: Vec<_> = workers.into_iter().map(|j| j.join().unwrap().unwrap()).collect();
    let late = reports.iter().find(|r| r.worker == 3).expect("late joiner registered");
    assert!(late.iterations > 0, "the late joiner must receive real work: {reports:?}");
}

/// A SIGTERM that lands while the master is blocked in `poll(2)` wakes it
/// through the signal self-pipe immediately — bounded by scheduling noise,
/// not by the old 200 ms poll-slice quantization.
#[test]
fn sigterm_wakes_a_blocked_master_immediately() {
    let _wd = Watchdog::arm("sigterm_wakes_a_blocked_master_immediately", Duration::from_secs(60));
    let flag = rdlb::util::signal::install_shutdown_handler();
    let params = NetMasterParams::new(8, 1, Technique::Fac, true);
    let cfg = MasterConfig {
        n: 8,
        p: 1,
        technique: Technique::Fac,
        params: params.tech_params.clone(),
        rdlb: true,
        health: HealthPolicy::default(),
    };
    let mut params = params;
    params.timeout = Duration::from_secs(30);
    let engine = Engine::new(cfg);
    let master = NetMaster::new(params).unwrap();

    // One connection held open whose peer never says Hello: with no tick
    // armed and a 30 s hang bound, the only thing that can wake the poll
    // is the signal.
    let (master_end, _held_open) = LoopbackTransport::pair();
    let (raised_tx, raised_rx) = std::sync::mpsc::channel::<Instant>();
    let raiser = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(250));
        extern "C" {
            fn raise(sig: std::ffi::c_int) -> std::ffi::c_int;
        }
        const SIGTERM: std::ffi::c_int = 15;
        unsafe { raise(SIGTERM) };
        raised_tx.send(Instant::now()).unwrap();
    });

    let (outcome, _engine) = master
        .run_session(engine, vec![Some(Box::new(master_end) as Box<dyn Transport>)], Some(flag))
        .unwrap();
    let returned = Instant::now();
    raiser.join().unwrap();
    let raised = raised_rx.recv().unwrap();

    assert!(!outcome.completed());
    assert!(!outcome.hung, "graceful shutdown is not a hang: {outcome:?}");
    let latency = returned.saturating_duration_since(raised);
    assert!(
        latency < Duration::from_millis(150),
        "signal-to-return latency {latency:?} — a poll-slice master would take ~200 ms+"
    );
}

/// The compatibility bridge: a master handed an *opaque* transport (the
/// chaos fault wrapper has no single pollable fd) pumps it through a local
/// socketpair and the run still completes with full parity semantics.
#[test]
fn master_over_opaque_fault_wrapper_completes() {
    let _wd = Watchdog::arm("master_over_opaque_fault_wrapper_completes", Duration::from_secs(120));
    let n = 200;
    let mut connections: Vec<Box<dyn Transport>> = Vec::new();
    let mut joins = Vec::new();
    for w in 0..2 {
        let (master_end, worker_end) = LoopbackTransport::pair();
        // A quiet plan injects nothing; what this exercises is the bridge
        // path itself (Pollable::Opaque -> socketpair pump).
        connections.push(Box::new(FaultInjectingTransport::new(
            Box::new(master_end),
            WireFaultPlan::quiet(0xB21D_6E00 + w as u64),
        )));
        let backend = synthetic(n, 1e-4);
        joins.push(std::thread::spawn(move || run_worker(Box::new(worker_end), backend, "bridge")));
    }
    let mut params = NetMasterParams::new(n, 2, Technique::Fac, true);
    params.timeout = Duration::from_secs(60);
    let outcome = NetMaster::new(params).unwrap().run(connections).unwrap();
    assert!(outcome.completed(), "{outcome:?}");
    assert_eq!(outcome.finished, n);
    for j in joins {
        j.join().unwrap().unwrap();
    }
}
