//! Property-based tests of the engine's event-sourced crash recovery
//! (seeded randomized cases over the in-tree PRNG — proptest is
//! unavailable offline, so each property is checked across generated
//! cases and failures print the offending seed).
//!
//! Each case drives a live engine through a protocol-valid random event
//! stream — requests, results with per-task digests, fail-stops that
//! strand in-flight chunks, version refusals, a terminal timeout on
//! expected-hang schedules — with the journal tap installed, then demands:
//!
//!  * **prefix fidelity** — [`Engine::replay`] over ANY journal prefix
//!    reconstructs exactly the live engine's state at that point in the
//!    run, byte-for-byte under the snapshot codec;
//!  * **resume equivalence** — [`Engine::restore`] of a mid-run snapshot
//!    plus [`Engine::replay_records`] over the journal suffix lands in the
//!    same state as replaying the whole journal (the `--resume` fast path
//!    equals the slow path);
//!  * **tap completeness** — the journal holds one record per handled
//!    event; nothing is silently dropped.

use std::sync::{Arc, Mutex};

use rdlb::coordinator::{
    Assignment, Effect, Engine, EngineEvent, HealthPolicy, MasterConfig, SharedSink,
};
use rdlb::dls::{Technique, TechniqueParams};
use rdlb::obs::{read_journal, JournalSink};
use rdlb::util::Rng;

/// Drives one engine through a random valid event stream, recording the
/// live snapshot after every handled event.
struct Driver {
    engine: Engine,
    /// `snapshots[i]` = live engine state right after journal record `i`.
    snapshots: Vec<Vec<u8>>,
    rng: Rng,
    /// In-flight assignments whose workers are still alive.
    pending: Vec<(usize, Assignment)>,
    /// Worker dies after this many served requests (`None` = never).
    fail_after: Vec<Option<usize>>,
    requests_served: Vec<usize>,
    alive: Vec<bool>,
    complete: bool,
    now: f64,
}

impl Driver {
    fn new(engine: Engine, seed: u64, fail_after: Vec<Option<usize>>) -> Driver {
        let p = fail_after.len();
        Driver {
            engine,
            snapshots: Vec::new(),
            rng: Rng::new(seed ^ 0xD21F),
            pending: Vec::new(),
            fail_after,
            requests_served: vec![0; p],
            alive: vec![true; p],
            complete: false,
            now: 0.0,
        }
    }

    /// Feed one event, snapshot the resulting state, return the effects.
    fn step(&mut self, event: EngineEvent<'_>) -> Vec<Effect> {
        let mut out = Vec::new();
        self.now += 1.0;
        self.engine.handle(self.now, event, &mut out);
        self.snapshots.push(self.engine.snapshot());
        out
    }

    /// One worker request, honoring the effect contract (exactly one of
    /// Assign / Park / TerminateWorker).  An assignment handed to a worker
    /// at its death point is lost — the crash-recovery scenario the journal
    /// must survive.
    fn request(&mut self, w: usize) {
        let effects = self.step(EngineEvent::WorkerRequest { worker: w });
        assert_eq!(effects.len(), 1, "request must yield exactly one effect: {effects:?}");
        self.requests_served[w] += 1;
        match effects.into_iter().next().unwrap() {
            Effect::Assign(a) => {
                let dies = self.fail_after[w].is_some_and(|k| self.requests_served[w] >= k);
                if dies {
                    self.alive[w] = false; // chunk evaporates mid-compute
                } else {
                    self.pending.push((w, a));
                }
            }
            Effect::Park { .. } | Effect::TerminateWorker { .. } => {}
            other => panic!("request produced {other:?}"),
        }
    }

    /// Deliver one pending result with per-task digests, then serve the
    /// wake pass and the reporter's piggy-backed request like the real
    /// drivers do.
    fn deliver(&mut self, idx: usize) {
        let (w, a) = self.pending.swap_remove(idx);
        let ids = a.tasks.to_vec();
        let digests: Vec<f64> = ids.iter().map(|&id| 1.0 + id as f64 * 0.25).collect();
        let effects = self.step(EngineEvent::ResultReceived {
            worker: w,
            assignment_id: a.id,
            compute_secs: 1e-3 * ids.len() as f64,
            digests: &digests,
        });
        let mut wakes = Vec::new();
        for eff in &effects {
            match eff {
                Effect::Completed => {
                    self.complete = true;
                    return;
                }
                Effect::Wake { worker } => wakes.push(*worker),
                other => panic!("result produced {other:?}"),
            }
        }
        for ww in wakes {
            self.request(ww);
        }
        if self.alive[w] {
            self.request(w);
        }
    }

    /// Run the stream to completion or to a documented hang.
    fn run(&mut self, refused: Option<usize>) {
        let p = self.alive.len();
        if let Some(w) = refused {
            self.alive[w] = false;
            let effects = self.step(EngineEvent::VersionRefused { worker: w });
            assert!(matches!(effects.as_slice(), [Effect::TerminateWorker { .. }]));
        }
        for w in 0..p {
            if self.alive[w] {
                self.request(w);
                if self.complete {
                    return;
                }
            }
        }
        let mut guard = 0usize;
        while !self.complete {
            if self.pending.is_empty() {
                // No live in-flight work and no completion: the documented
                // hang (lost chunks without rDLB, or everyone refused/dead).
                self.step(EngineEvent::Timeout);
                assert!(self.engine.hung(), "empty pipeline without completion must hang");
                return;
            }
            let idx = self.rng.gen_range(0, (self.pending.len() - 1) as u64) as usize;
            self.deliver(idx);
            guard += 1;
            assert!(guard < 100_000, "runaway stream");
        }
    }
}

/// Build one random case: config, fault plan, optional refused worker.
fn random_case(seed: u64) -> (MasterConfig, Vec<Option<usize>>, Option<usize>) {
    let mut rng = Rng::new(seed);
    let techniques = [
        Technique::Ss,
        Technique::Gss,
        Technique::Fac,
        Technique::Tss,
        Technique::AwfC,
        Technique::Af,
    ];
    let n = 16 + (rng.next_u64() % 100) as usize;
    let p = 2 + (rng.next_u64() % 5) as usize;
    let technique = techniques[(rng.next_u64() % 6) as usize];
    let rdlb = rng.next_f64() < 0.7;
    let cfg = MasterConfig {
        n,
        p,
        technique,
        params: TechniqueParams::default(),
        rdlb,
        health: HealthPolicy::default(),
    };
    // Worker 0 pristine; others may die after a few served requests.
    let fail_after: Vec<Option<usize>> = (0..p)
        .map(|w| (w != 0 && rng.next_f64() < 0.35).then(|| 1 + (rng.next_u64() % 4) as usize))
        .collect();
    let refused = (p > 2 && rng.next_f64() < 0.25).then(|| p - 1);
    (cfg, fail_after, refused)
}

#[test]
fn prop_replay_of_any_prefix_matches_the_live_engine() {
    for seed in 0..24u64 {
        let (cfg, fail_after, refused) = random_case(seed);
        let tap = Arc::new(Mutex::new(JournalSink::new()));
        let mut engine = Engine::new(cfg.clone());
        engine.set_sink(0, Box::new(SharedSink::from_arc(tap.clone())));
        let mut driver = Driver::new(engine, seed, fail_after);
        driver.run(refused);

        let bytes = tap.lock().unwrap().bytes().to_vec();
        let records = read_journal(&bytes).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert_eq!(
            records.len(),
            driver.snapshots.len(),
            "seed {seed}: one journal record per handled event"
        );

        // The empty prefix is a fresh engine...
        assert_eq!(
            Engine::replay(cfg.clone(), &[]).unwrap().snapshot(),
            Engine::new(cfg.clone()).snapshot(),
            "seed {seed}"
        );
        // ...and every other prefix replays to the exact live state at that
        // point (stride the long tails to keep the quadratic cost bounded).
        let len = records.len();
        let stride = 1 + len / 64;
        for k in (1..=len).filter(|k| k % stride == 0 || *k == len) {
            let replayed = Engine::replay(cfg.clone(), &records[..k])
                .unwrap_or_else(|e| panic!("seed {seed} prefix {k}: {e:#}"));
            assert_eq!(
                replayed.snapshot(),
                driver.snapshots[k - 1],
                "seed {seed}: prefix {k}/{len} diverges from the live engine"
            );
        }
    }
}

/// Feed one event to a live engine, snapshot the resulting state, return
/// the effects (scripted sibling of [`Driver::step`]).
fn feed_and_snap(
    e: &mut Engine,
    snaps: &mut Vec<Vec<u8>>,
    now: f64,
    ev: EngineEvent<'_>,
) -> Vec<Effect> {
    let mut out = Vec::new();
    e.handle(now, ev, &mut out);
    snaps.push(e.snapshot());
    out
}

fn take_assign(effects: Vec<Effect>) -> Assignment {
    match effects.into_iter().next() {
        Some(Effect::Assign(a)) => a,
        other => panic!("expected Assign, got {other:?}"),
    }
}

#[test]
fn health_deadline_state_round_trips_through_snapshot_and_replay() {
    // A health-armed scripted run: per-worker rate estimates, deadline
    // anchors, overdue flags, the speculation queue and quarantine state
    // must all survive the snapshot codec, and journal replay must
    // reconstruct them exactly — otherwise a resumed master would forget
    // which chunks it already flagged and re-speculate or re-quarantine.
    let cfg = MasterConfig {
        n: 4,
        p: 2,
        technique: Technique::Ss,
        params: TechniqueParams::default(),
        rdlb: true,
        health: HealthPolicy {
            enabled: true,
            slack: 2.0,
            floor_secs: 0.001,
            quarantine_k: 1,
            min_pool: 1,
            tick_secs: 0.5,
        },
    };
    let tap = Arc::new(Mutex::new(JournalSink::new()));
    let mut engine = Engine::new(cfg.clone());
    engine.set_sink(0, Box::new(SharedSink::from_arc(tap.clone())));
    let mut snaps: Vec<Vec<u8>> = Vec::new();

    // w0 takes task 0 and goes silent; w1 takes task 1 and finishes fast,
    // seeding the rate estimator; a heartbeat refreshes w0's anchor.
    let a0 = take_assign(feed_and_snap(&mut engine, &mut snaps, 0.0, EngineEvent::WorkerRequest {
        worker: 0,
    }));
    let a1 = take_assign(feed_and_snap(&mut engine, &mut snaps, 0.1, EngineEvent::WorkerRequest {
        worker: 1,
    }));
    assert!(feed_and_snap(&mut engine, &mut snaps, 0.2, EngineEvent::ResultReceived {
        worker: 1,
        assignment_id: a1.id,
        compute_secs: 0.1,
        digests: &[1.25],
    })
    .is_empty());
    assert!(feed_and_snap(&mut engine, &mut snaps, 0.25, EngineEvent::Progress { worker: 0 })
        .is_empty());

    // The tick flags w0's chunk (window = 0.1s pooled rate × 2.0 slack,
    // age 0.75s from the refreshed anchor) and quarantines w0 (k = 1).
    assert_eq!(
        feed_and_snap(&mut engine, &mut snaps, 1.0, EngineEvent::HealthTick),
        vec![Effect::Overdue { worker: 0, assignment_id: a0.id, quarantined: true }]
    );
    // w1 picks up the speculative copy; quarantined w0 parks; then w0's
    // own late result lands first, lifting the quarantine and waking it.
    let spec = take_assign(feed_and_snap(&mut engine, &mut snaps, 1.1, EngineEvent::WorkerRequest {
        worker: 1,
    }));
    assert!(spec.rescheduled);
    assert_eq!(
        feed_and_snap(&mut engine, &mut snaps, 1.2, EngineEvent::WorkerRequest { worker: 0 }),
        vec![Effect::Park { worker: 0 }]
    );
    assert_eq!(
        feed_and_snap(&mut engine, &mut snaps, 1.3, EngineEvent::ResultReceived {
            worker: 0,
            assignment_id: a0.id,
            compute_secs: 1.3,
            digests: &[2.0],
        }),
        vec![Effect::Wake { worker: 0 }]
    );
    // Drain the rest of the run, the duplicate speculative result included.
    let a2 = take_assign(feed_and_snap(&mut engine, &mut snaps, 1.4, EngineEvent::WorkerRequest {
        worker: 0,
    }));
    assert!(!a2.rescheduled);
    assert!(feed_and_snap(&mut engine, &mut snaps, 1.5, EngineEvent::ResultReceived {
        worker: 1,
        assignment_id: spec.id,
        compute_secs: 0.4,
        digests: &[9.0],
    })
    .is_empty());
    assert!(feed_and_snap(&mut engine, &mut snaps, 1.6, EngineEvent::ResultReceived {
        worker: 0,
        assignment_id: a2.id,
        compute_secs: 0.2,
        digests: &[3.0],
    })
    .is_empty());
    let a3 = take_assign(feed_and_snap(&mut engine, &mut snaps, 1.7, EngineEvent::WorkerRequest {
        worker: 0,
    }));
    assert_eq!(
        feed_and_snap(&mut engine, &mut snaps, 1.8, EngineEvent::ResultReceived {
            worker: 0,
            assignment_id: a3.id,
            compute_secs: 0.1,
            digests: &[4.0],
        }),
        vec![Effect::Completed]
    );

    // Every journal prefix replays to the exact live state at that point —
    // including the prefixes that end mid-quarantine and mid-speculation.
    let bytes = tap.lock().unwrap().bytes().to_vec();
    let records = read_journal(&bytes).unwrap();
    assert_eq!(records.len(), snaps.len(), "one journal record per handled event");
    for k in 1..=records.len() {
        let replayed = Engine::replay(cfg.clone(), &records[..k])
            .unwrap_or_else(|e| panic!("prefix {k}: {e:#}"));
        assert_eq!(
            replayed.snapshot(),
            snaps[k - 1],
            "prefix {k}/{} diverges from the live engine",
            records.len()
        );
    }
    // Resume fast path across the health-critical boundary: restore the
    // snapshot taken right after the HealthTick, replay the suffix.
    let full = snaps.last().unwrap();
    let mut resumed = Engine::restore(&snaps[4]).unwrap();
    resumed.replay_records(&records[5..]).unwrap();
    assert_eq!(resumed.snapshot(), *full, "snapshot@tick + suffix diverges from full replay");
}

#[test]
fn prop_snapshot_plus_suffix_equals_full_replay() {
    for seed in 100..118u64 {
        let (cfg, fail_after, refused) = random_case(seed);
        let tap = Arc::new(Mutex::new(JournalSink::new()));
        let mut engine = Engine::new(cfg.clone());
        engine.set_sink(0, Box::new(SharedSink::from_arc(tap.clone())));
        let mut driver = Driver::new(engine, seed, fail_after);
        driver.run(refused);

        let bytes = tap.lock().unwrap().bytes().to_vec();
        let records = read_journal(&bytes).unwrap();
        let len = records.len();
        assert!(len > 0, "seed {seed}: empty stream");
        let full = Engine::replay(cfg.clone(), &records).unwrap().snapshot();
        assert_eq!(full, *driver.snapshots.last().unwrap(), "seed {seed}: full replay");
        for k in [len / 3, len / 2, 2 * len / 3] {
            if k == 0 || k >= len {
                continue;
            }
            // Resume fast path: restore the snapshot covering k records,
            // then replay only the suffix.
            let mut resumed = Engine::restore(&driver.snapshots[k - 1])
                .unwrap_or_else(|e| panic!("seed {seed} restore@{k}: {e:#}"));
            resumed
                .replay_records(&records[k..])
                .unwrap_or_else(|e| panic!("seed {seed} suffix@{k}: {e:#}"));
            assert_eq!(
                resumed.snapshot(),
                full,
                "seed {seed}: snapshot@{k} + suffix diverges from full replay"
            );
        }
    }
}
