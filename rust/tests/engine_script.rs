//! Scripted-driver tests for the sans-I/O coordinator engine: hand-written
//! event sequences, asserted effect sequences, and `MasterStats`
//! identities — no threads, no sockets, no clocks.
//!
//! These scripts are the executable specification of the engine/driver
//! contract (see `ARCHITECTURE.md`): every runtime is a thin translator
//! around exactly these effect sequences, so behavior pinned here is pinned
//! for the simulator, the native threads, the net runtime and both levels
//! of the hierarchical runtime at once.

use rdlb::coordinator::{Assignment, Effect, Engine, EngineEvent, HealthPolicy, MasterConfig};
use rdlb::dls::{Technique, TechniqueParams};

fn engine(n: usize, p: usize, technique: Technique, rdlb: bool) -> Engine {
    Engine::new(MasterConfig {
        n,
        p,
        technique,
        params: TechniqueParams::default(),
        rdlb,
        health: HealthPolicy::default(),
    })
}

/// An engine with the worker-health layer armed under `policy`.
fn health_engine(n: usize, p: usize, technique: Technique, policy: HealthPolicy) -> Engine {
    Engine::new(MasterConfig {
        n,
        p,
        technique,
        params: TechniqueParams::default(),
        rdlb: true,
        health: policy,
    })
}

/// Feed one event, returning the full effect list.
fn feed(e: &mut Engine, now: f64, ev: EngineEvent<'_>) -> Vec<Effect> {
    let mut out = Vec::new();
    e.handle(now, ev, &mut out);
    out
}

/// Feed a `WorkerRequest` and unwrap the promised single `Assign`.
fn assign(e: &mut Engine, worker: usize, now: f64) -> Assignment {
    let mut out = feed(e, now, EngineEvent::WorkerRequest { worker });
    assert_eq!(out.len(), 1, "a request yields exactly one effect: {out:?}");
    match out.pop().unwrap() {
        Effect::Assign(a) => {
            assert_eq!(a.worker, worker);
            a
        }
        other => panic!("expected Assign for worker {worker}, got {other:?}"),
    }
}

fn result_event(worker: usize, id: u64, digests: &[f64]) -> EngineEvent<'_> {
    EngineEvent::ResultReceived {
        worker,
        assignment_id: id,
        compute_secs: 0.01,
        digests,
    }
}

/// Drive the scripted state where worker 0 holds every pending iteration
/// and is parked, with worker 1's original chunk for task 1 still in
/// flight.  Returns `(engine, a0, a1, dup)`:
/// task 0 held by w0 (a0), task 1 held by w1 (a1) and duplicated by w0
/// (dup).
fn parked_holder_state() -> (Engine, Assignment, Assignment, Assignment) {
    let mut e = engine(2, 2, Technique::Gss, true);
    let a0 = assign(&mut e, 0, 0.0); // primary: task 0
    assert_eq!(a0.tasks.to_vec(), vec![0]);
    let a1 = assign(&mut e, 1, 0.0); // primary: task 1
    assert_eq!(a1.tasks.to_vec(), vec![1]);
    // Everything is Scheduled: w0's next request enters the rDLB phase and
    // duplicates the one pending task it does not hold — task 1.
    let dup = assign(&mut e, 0, 0.1);
    assert!(dup.rescheduled);
    assert_eq!(dup.tasks.to_vec(), vec![1]);
    // Now w0 holds both pending tasks: its request parks.
    let out = feed(&mut e, 0.2, EngineEvent::WorkerRequest { worker: 0 });
    assert_eq!(out, vec![Effect::Park { worker: 0 }]);
    (e, a0, a1, dup)
}

#[test]
fn park_then_wake_on_first_completion() {
    let (mut e, _a0, _a1, dup) = parked_holder_state();
    // w0 completes its duplicate of task 1: a FIRST completion (w1 has not
    // reported).  The run is not complete (task 0 pending), so the parked
    // w0 is woken — in park order, as the one and only effect.
    let d = [1.0];
    let out = feed(&mut e, 0.3, result_event(0, dup.id, &d));
    assert_eq!(out, vec![Effect::Wake { worker: 0 }], "pool shrank: parked worker must wake");
    // The wake delivery: w0 still holds pending task 0, so it re-parks.
    let out = feed(&mut e, 0.3, EngineEvent::WorkerRequest { worker: 0 });
    assert_eq!(out, vec![Effect::Park { worker: 0 }]);
    let stats = e.final_stats();
    assert_eq!(stats.finished_iterations, 1);
    assert_eq!(stats.duplicate_iterations, 0);
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

/// The uniform park/wake behavior decision, pinned: an **all-duplicate**
/// result (nothing newly finished — the pool did not shrink) still wakes
/// every parked worker, because a completion also releases the reporter's
/// holds and "never hand a worker an iteration it already holds" can be
/// what parked them.  Before the engine extraction each runtime hand-rolled
/// this pass and the three copies had begun to drift; any future divergence
/// fails this script for all runtimes at once.
#[test]
fn duplicate_result_still_wakes_parked_workers() {
    let (mut e, a0, a1, dup) = parked_holder_state();
    let d = [1.0];
    // First completion of task 1 via w0's duplicate; w0 wakes and re-parks.
    assert_eq!(feed(&mut e, 0.3, result_event(0, dup.id, &d)), vec![Effect::Wake { worker: 0 }]);
    assert_eq!(
        feed(&mut e, 0.3, EngineEvent::WorkerRequest { worker: 0 }),
        vec![Effect::Park { worker: 0 }]
    );
    // w1's original result for task 1 arrives late: ALL duplicate work.
    let out = feed(&mut e, 0.4, result_event(1, a1.id, &d));
    assert_eq!(
        out,
        vec![Effect::Wake { worker: 0 }],
        "an all-duplicate completion must still wake parked workers"
    );
    assert_eq!(e.final_stats().duplicate_iterations, 1);
    // w0 still holds the last pending task; re-parks once more.
    assert_eq!(
        feed(&mut e, 0.4, EngineEvent::WorkerRequest { worker: 0 }),
        vec![Effect::Park { worker: 0 }]
    );
    // Its own original chunk for task 0 completes the run: no further
    // wakes, just Completed.
    let out = feed(&mut e, 0.5, result_event(0, a0.id, &d));
    assert_eq!(out, vec![Effect::Completed]);
    let stats = e.final_stats();
    assert_eq!(stats.finished_iterations, 2);
    assert_eq!(stats.duplicate_iterations, 1);
    assert_eq!(e.result_digest(), 2.0, "exactly one digest contribution per iteration");
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

#[test]
fn mid_chunk_fail_stop_is_recovered_by_redispatch() {
    // w0 grabs the first GSS chunk and goes silent mid-chunk (the driver
    // simply never delivers a result — exactly what a fail-stop looks like
    // to the engine).  w1 alone must finish everything via re-dispatch.
    let n = 8;
    let mut e = engine(n, 2, Technique::Gss, true);
    let lost = assign(&mut e, 0, 0.0); // tasks 0..4, never completed
    assert_eq!(lost.tasks.to_vec(), vec![0, 1, 2, 3]);
    let digest_ones = vec![1.0f64; n];
    let mut redispatched = 0u64;
    let mut guard = 0;
    loop {
        let mut out = feed(&mut e, 1.0, EngineEvent::WorkerRequest { worker: 1 });
        assert_eq!(out.len(), 1);
        match out.pop().unwrap() {
            Effect::Assign(a) => {
                if a.rescheduled {
                    redispatched += 1;
                    for t in a.tasks.iter() {
                        assert!(lost.tasks.contains(t), "re-dispatch must cover the lost chunk");
                    }
                }
                let d = &digest_ones[..a.len()];
                let fx = feed(&mut e, 1.1, result_event(1, a.id, d));
                if fx == vec![Effect::Completed] {
                    break;
                }
                assert!(fx.is_empty(), "nothing parked: {fx:?}");
            }
            Effect::TerminateWorker { worker: 1 } => break,
            other => panic!("w1 must never park while work is pending: {other:?}"),
        }
        guard += 1;
        assert!(guard < 10 * n, "did not terminate");
    }
    assert!(e.is_complete());
    assert!(redispatched > 0, "the lost chunk must have been re-dispatched");
    let stats = e.final_stats();
    assert_eq!(stats.finished_iterations as usize, n);
    assert_eq!(stats.lost_chunks(), 1, "exactly w0's chunk was assigned but never completed");
    assert_eq!(e.result_digest(), n as f64);
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

#[test]
fn stale_version_refusal_terminates_and_is_counted() {
    let n = 4;
    let mut e = engine(n, 2, Technique::Fac, true);
    // Slot 1 registers with a stale protocol version; the driver reports
    // the refusal and must be told to terminate exactly that peer.
    let out = feed(&mut e, 0.0, EngineEvent::VersionRefused { worker: 1 });
    assert_eq!(out, vec![Effect::TerminateWorker { worker: 1 }]);
    // The surviving worker computes everything.
    let ones = [1.0f64; 4];
    let mut guard = 0;
    loop {
        let mut out = feed(&mut e, 1.0, EngineEvent::WorkerRequest { worker: 0 });
        match out.pop().unwrap() {
            Effect::Assign(a) => {
                let fx = feed(&mut e, 1.1, result_event(0, a.id, &ones[..a.len()]));
                if fx == vec![Effect::Completed] {
                    break;
                }
            }
            other => panic!("{other:?}"),
        }
        guard += 1;
        assert!(guard < 10 * n);
    }
    let stats = e.final_stats();
    assert_eq!(stats.refused_workers, 1, "refusal must be visible in the final stats");
    assert_eq!(stats.finished_iterations as usize, n);
    assert_eq!(e.result_digest(), n as f64);
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

#[test]
fn last_chunk_redispatch_races_and_attributes_once() {
    // Three SS chunks on three workers; w2 goes silent holding task 2.
    // Both idle workers duplicate the last pending chunk; the first copy
    // completes the run, the second is recorded as pure duplicate work and
    // must not contribute to the digest.
    let mut e = engine(3, 3, Technique::Ss, true);
    let a0 = assign(&mut e, 0, 0.0);
    let a1 = assign(&mut e, 1, 0.0);
    let _lost = assign(&mut e, 2, 0.0); // task 2, never completed
    let d = [1.0];
    assert!(feed(&mut e, 0.1, result_event(0, a0.id, &d)).is_empty());
    assert!(feed(&mut e, 0.1, result_event(1, a1.id, &d)).is_empty());
    // Both w0 and w1 now duplicate task 2 (neither holds it).
    let dup0 = assign(&mut e, 0, 0.2);
    let dup1 = assign(&mut e, 1, 0.2);
    assert!(dup0.rescheduled && dup1.rescheduled);
    assert_eq!(dup0.tasks.to_vec(), vec![2]);
    assert_eq!(dup1.tasks.to_vec(), vec![2]);
    // First copy home wins the run.
    let d2 = [7.0];
    assert_eq!(feed(&mut e, 0.3, result_event(0, dup0.id, &d2)), vec![Effect::Completed]);
    assert_eq!(e.result_digest(), 1.0 + 1.0 + 7.0);
    // The straggling second copy is tolerated, counted, and digest-inert.
    let fx = feed(&mut e, 0.4, result_event(1, dup1.id, &d2));
    assert_eq!(fx, vec![Effect::Completed], "post-completion results re-report Completed");
    assert_eq!(e.result_digest(), 1.0 + 1.0 + 7.0, "duplicate must not contribute");
    let stats = e.final_stats();
    assert_eq!(stats.finished_iterations, 3);
    assert_eq!(stats.duplicate_iterations, 1);
    assert_eq!(stats.rescheduled_chunks, 2);
    assert_eq!(stats.rescheduled_completions, 2);
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

/// The worker-health contract end to end: a chunk past its deadline is
/// flagged `Overdue` exactly once, its tasks are speculatively
/// re-dispatched *ahead of the primary phase*, and the straggler's late
/// result is absorbed as a digest-inert duplicate through the ordinary
/// first-completion filter — with every stats identity intact.
#[test]
fn overdue_chunk_is_speculated_and_late_straggler_result_is_suppressed() {
    let policy = HealthPolicy {
        slack: 2.0,
        floor_secs: 0.001,
        quarantine_k: 99, // quarantine out of the picture for this script
        ..HealthPolicy::on()
    };
    let mut e = health_engine(4, 2, Technique::Ss, policy);
    let a0 = assign(&mut e, 0, 0.0); // task 0 — w0 goes silent holding it
    assert_eq!(a0.tasks.to_vec(), vec![0]);
    let a1 = assign(&mut e, 1, 0.0); // task 1 — completes promptly
    assert_eq!(a1.tasks.to_vec(), vec![1]);
    // w1's completion seeds the rate estimate (~0.01 s per task); w0 has no
    // history, so its prediction falls back to the pooled mean.
    assert!(feed(&mut e, 0.01, result_event(1, a1.id, &[1.0])).is_empty());

    // Before any completion the tick is cold-start safe; afterwards w0's
    // chunk (age 1.0 s >> 0.02 s window) is flagged — once, not twice.
    let out = feed(&mut e, 1.0, EngineEvent::HealthTick);
    assert_eq!(
        out,
        vec![Effect::Overdue { worker: 0, assignment_id: a0.id, quarantined: false }]
    );
    assert!(feed(&mut e, 1.01, EngineEvent::HealthTick).is_empty(), "flagged at most once");

    // The overdue chunk is served to the next requester *before* the
    // primary phase, although tasks 2 and 3 are still unscheduled.
    let spec = assign(&mut e, 1, 1.1);
    assert!(spec.rescheduled, "speculative copies are rescheduled chunks");
    assert_eq!(spec.tasks.to_vec(), vec![0]);
    assert!(feed(&mut e, 1.15, result_event(1, spec.id, &[5.0])).is_empty());
    assert_eq!(e.result_digest(), 1.0 + 5.0, "the speculative copy won task 0");

    // Drain the primary phase.
    let a2 = assign(&mut e, 1, 1.2);
    assert_eq!(a2.tasks.to_vec(), vec![2]);
    assert!(feed(&mut e, 1.25, result_event(1, a2.id, &[1.0])).is_empty());
    let a3 = assign(&mut e, 1, 1.3);
    assert_eq!(a3.tasks.to_vec(), vec![3]);
    assert_eq!(feed(&mut e, 1.35, result_event(1, a3.id, &[1.0])), vec![Effect::Completed]);

    // The straggler finally reports: tolerated, counted, digest-inert.
    assert_eq!(feed(&mut e, 3.0, result_event(0, a0.id, &[9.0])), vec![Effect::Completed]);
    assert_eq!(e.result_digest(), 8.0, "late duplicate must not contribute");
    let stats = e.final_stats();
    assert_eq!(stats.finished_iterations, 4);
    assert_eq!(stats.duplicate_iterations, 1);
    assert_eq!(stats.overdue_chunks, 1);
    assert_eq!(stats.rescheduled_chunks, 1);
    assert_eq!(stats.quarantined_workers, 0);
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

/// Quarantine enter/exit, scripted: K consecutive overdue verdicts park a
/// worker with prejudice (requests Wait), the min-pool floor stops the
/// *last* eligible workers from being quarantined, and one clean completion
/// lifts the quarantine and makes the worker schedulable again.
#[test]
fn quarantine_enters_respects_min_pool_and_exits_on_clean_completion() {
    let policy = HealthPolicy {
        slack: 1.5,
        floor_secs: 0.001,
        quarantine_k: 1, // first overdue verdict quarantines
        min_pool: 1,
        ..HealthPolicy::on()
    };
    let mut e = health_engine(6, 2, Technique::Ss, policy);
    let a0 = assign(&mut e, 0, 0.0); // task 0 — stalls
    let a1 = assign(&mut e, 1, 0.0); // task 1 — completes, seeding rates
    assert!(feed(&mut e, 0.05, result_event(1, a1.id, &[1.0])).is_empty());

    // w0 blows its deadline; k=1 pushes it straight into quarantine.
    let out = feed(&mut e, 1.0, EngineEvent::HealthTick);
    assert_eq!(
        out,
        vec![Effect::Overdue { worker: 0, assignment_id: a0.id, quarantined: true }]
    );
    // Parked with prejudice: no new work for w0 while quarantined.
    assert_eq!(
        feed(&mut e, 1.05, EngineEvent::WorkerRequest { worker: 0 }),
        vec![Effect::Park { worker: 0 }]
    );
    // w1 picks up the speculative copy of w0's chunk... and stalls too.
    let spec = assign(&mut e, 1, 1.1);
    assert!(spec.rescheduled);
    assert_eq!(spec.tasks.to_vec(), vec![0]);
    let out = feed(&mut e, 5.0, EngineEvent::HealthTick);
    assert_eq!(
        out,
        vec![
            // The min-pool floor keeps the last eligible worker
            // unquarantined...
            Effect::Overdue { worker: 1, assignment_id: spec.id, quarantined: false },
            // ...and a tick that flagged anything wakes parked workers —
            // even quarantined ones, which simply re-park on their retry.
            Effect::Wake { worker: 0 },
        ]
    );
    assert_eq!(
        feed(&mut e, 5.01, EngineEvent::WorkerRequest { worker: 0 }),
        vec![Effect::Park { worker: 0 }]
    );

    // The original straggler's result lands first: a clean completion that
    // lifts its quarantine and wakes it (it was parked).
    let out = feed(&mut e, 5.1, result_event(0, a0.id, &[2.0]));
    assert_eq!(out, vec![Effect::Wake { worker: 0 }]);
    let revived = assign(&mut e, 0, 5.2);
    assert!(!revived.rescheduled, "quarantine lifted: w0 draws primary work again");
    assert_eq!(revived.tasks.to_vec(), vec![2]);

    // w1's stalled duplicate of task 0 eventually reports: digest-inert.
    assert!(feed(&mut e, 5.3, result_event(1, spec.id, &[9.0])).is_empty());
    assert_eq!(e.result_digest(), 1.0 + 2.0, "duplicate of task 0 must not contribute");
    let stats = e.final_stats();
    assert_eq!(stats.overdue_chunks, 2);
    assert_eq!(stats.quarantined_workers, 1, "only w0 ever entered quarantine");
    assert_eq!(stats.duplicate_iterations, 1);
    assert_eq!(stats.identity_violations(), Vec::<String>::new());
}

#[test]
fn timeout_and_disconnect_are_inert_bookkeeping() {
    let mut e = engine(4, 2, Technique::Fac, true);
    let _a = assign(&mut e, 0, 0.0);
    assert!(feed(&mut e, 0.1, EngineEvent::WorkerDisconnected { worker: 1 }).is_empty());
    assert_eq!(e.disconnects(), 1);
    assert!(!e.hung());
    assert!(feed(&mut e, 60.0, EngineEvent::Timeout).is_empty());
    assert!(e.hung(), "timeout before completion records the hang");
    assert_eq!(e.final_stats().identity_violations(), Vec::<String>::new());
}
