//! Integration tests for the two-level hierarchical runtime: completion
//! and exact digest parity with the serial kernel under group-master
//! fail-stops and worker failures, hang documentation without rDLB, and
//! invariance of the digest across group shapes.

use std::sync::Arc;
use std::time::Duration;

use rdlb::apps::{CostModel, MandelbrotApp};
use rdlb::dls::Technique;
use rdlb::hier::{HierParams, HierRuntime};
use rdlb::native::ComputeBackend;
use rdlb::util::Watchdog;

fn synthetic(n: usize, cost: f64) -> ComputeBackend {
    ComputeBackend::Synthetic { model: Arc::new(CostModel::from_costs(vec![cost; n])), scale: 1.0 }
}

/// The acceptance scenario: a group-master fail-stop PLUS W−1 worker
/// failures inside a surviving group, with digest parity against the
/// serial kernel.  Two groups of three: group 1's master (global worker 3)
/// dies mid-run — taking its workers 4 and 5 with it, which also fail on
/// their own schedule — and group 0 loses workers 1 and 2, leaving global
/// worker 0 alone (P−1 = 5 failed PEs).  rDLB at both levels must still
/// finish every iteration exactly once.
#[test]
fn group_master_failure_plus_p_minus_1_workers_completes_with_digest_parity() {
    let _guard = Watchdog::arm("hier_group_master_failure", Duration::from_secs(120));
    let n = 400;
    let mut p = HierParams::new(n, 2, 3, Technique::Fac, true, synthetic(n, 2e-3));
    // Failure-free makespan ≈ n·cost/6 ≈ 130 ms: these all land mid-run.
    p.failures[3] = Some(0.05); // group 1's master slot: the whole group dies
    p.failures[4] = Some(0.06);
    p.failures[5] = Some(0.07);
    p.failures[1] = Some(0.08); // surviving group 0 loses W−1 workers...
    p.failures[2] = Some(0.11); // ...leaving only global worker 0
    p.timeout = Duration::from_secs(60);
    let o = HierRuntime::new(p).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    assert_eq!(o.finished, n);
    assert_eq!(o.failures, 5);
    assert_eq!(
        o.result_digest, n as f64,
        "serial-kernel digest parity (1.0 per task, exactly once): {o:?}"
    );
    assert!(o.stats.identity_violations().is_empty(), "{:?}", o.stats);
}

/// Same shape on the Mandelbrot kernel, whose per-task digests are all
/// distinct — a misattributed or double-counted iteration cannot cancel
/// out.  Fail times are tiny (the kernel is fast); whether each failure
/// fires before, during or after the chunk stream, parity must hold.
#[test]
fn hier_mandelbrot_digest_matches_serial_kernel_under_failures() {
    let _guard = Watchdog::arm("hier_mandelbrot_parity", Duration::from_secs(120));
    let app = MandelbrotApp { width: 32, height: 32, max_iter: 64, ..Default::default() };
    let n = app.n_tasks();
    let serial: f64 = app.compute_range(0, n as u32).iter().map(|&c| c as f64).sum();
    let backend = ComputeBackend::Mandelbrot(Arc::new(app));
    let mut p = HierParams::new(n, 2, 2, Technique::Gss, true, backend);
    p.failures[2] = Some(0.002); // group 1's master
    p.failures[1] = Some(0.003); // group 0's second worker
    p.timeout = Duration::from_secs(60);
    let o = HierRuntime::new(p).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    assert_eq!(o.result_digest, serial, "hier ↔ serial digest parity: {o:?}");
}

#[test]
fn hier_digest_invariant_across_runs_and_group_shapes() {
    let _guard = Watchdog::arm("hier_digest_invariance", Duration::from_secs(120));
    let n = 240;
    let run = |groups: usize, wpg: usize| {
        let mut p = HierParams::new(n, groups, wpg, Technique::Fac, true, synthetic(n, 1e-4));
        p.timeout = Duration::from_secs(30);
        let o = HierRuntime::new(p).unwrap().run().unwrap();
        assert!(o.completed(), "{groups}x{wpg}: {o:?}");
        o.result_digest
    };
    assert_eq!(run(2, 3), n as f64);
    assert_eq!(run(2, 3), run(2, 3), "same shape twice must agree exactly");
    assert_eq!(run(2, 3), run(3, 2), "digest must not depend on the group shape");
}

/// The paper's documented failure mode survives the hierarchy: without
/// rDLB a lost chunk (here: a whole lost group) hangs the run, reported at
/// the wall-clock bound instead of completing wrongly.
#[test]
fn hier_failure_without_rdlb_hangs_at_the_bound() {
    let _guard = Watchdog::arm("hier_hang_documented", Duration::from_secs(120));
    let n = 160;
    let mut p = HierParams::new(n, 2, 2, Technique::Fac, false, synthetic(n, 2e-3));
    p.failures[2] = Some(0.02); // group 1's master dies holding a super-chunk
    p.timeout = Duration::from_millis(900);
    let o = HierRuntime::new(p).unwrap().run().unwrap();
    assert!(o.hung, "must hang without rDLB: {o:?}");
    assert!(o.parallel_time.is_infinite());
    assert!(o.finished < n, "work must demonstrably be missing");
}
