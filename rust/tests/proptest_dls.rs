//! Property-based tests over the 14 DLS techniques: randomized (N, P)
//! sweeps checking the scheduling invariants every technique must satisfy.

use rdlb::dls::{ChunkFeedback, SchedCtx, Technique, TechniqueParams};
use rdlb::util::Rng;

fn ctx(n: usize, p: usize, remaining: usize, worker: usize, idx: usize) -> SchedCtx {
    SchedCtx { n, p, remaining, worker, chunk_index: idx, now: idx as f64 }
}

/// Drain a technique to exhaustion with round-robin workers + feedback.
fn drain(technique: Technique, n: usize, p: usize, seed: u64) -> Vec<usize> {
    let params = TechniqueParams { seed, ..Default::default() };
    let mut calc = technique.calculator(n, p, &params);
    let mut rng = Rng::new(seed ^ 0x51ED);
    let mut remaining = n;
    let mut out = Vec::new();
    let mut idx = 0;
    while remaining > 0 {
        let w = idx % p;
        let c = calc.next_chunk(&ctx(n, p, remaining, w, idx));
        assert!(
            (1..=remaining).contains(&c),
            "{technique}: chunk {c} outside 1..={remaining} (n={n} p={p})"
        );
        out.push(c);
        remaining -= c;
        // Plausible noisy feedback so adaptive techniques exercise their
        // update paths.
        calc.feedback(&ChunkFeedback {
            worker: w,
            chunk_size: c,
            compute_time: c as f64 * (1e-3 + 1e-4 * rng.next_f64()),
            sched_overhead: 1e-5,
            now: idx as f64,
            batch_done: false,
        });
        idx += 1;
        assert!(idx <= 10 * n + 100, "{technique}: non-terminating (n={n} p={p})");
    }
    out
}

#[test]
fn prop_all_techniques_conserve_and_terminate() {
    let mut rng = Rng::new(99);
    for _ in 0..25 {
        let n = 1 + (rng.next_u64() % 30_000) as usize;
        let p = 1 + (rng.next_u64() % 64) as usize;
        for t in Technique::ALL {
            let seq = drain(t, n, p, rng.next_u64());
            assert_eq!(seq.iter().sum::<usize>(), n, "{t}: lost iterations (n={n} p={p})");
        }
    }
}

#[test]
fn prop_decreasing_techniques_never_increase_before_tail() {
    // GSS and TSS produce non-increasing chunk sizes (monotone schedules).
    let mut rng = Rng::new(7);
    for _ in 0..20 {
        let n = 100 + (rng.next_u64() % 50_000) as usize;
        let p = 2 + (rng.next_u64() % 32) as usize;
        for t in [Technique::Gss, Technique::Tss] {
            let seq = drain(t, n, p, 1);
            assert!(
                seq.windows(2).all(|w| w[1] <= w[0]),
                "{t}: increasing chunk in {seq:?} (n={n} p={p})"
            );
        }
    }
}

#[test]
fn prop_fixed_size_techniques_are_constant_until_tail() {
    let mut rng = Rng::new(13);
    for _ in 0..20 {
        let n = 100 + (rng.next_u64() % 50_000) as usize;
        let p = 2 + (rng.next_u64() % 32) as usize;
        for t in [Technique::Fsc, Technique::MFsc, Technique::Static] {
            let seq = drain(t, n, p, 1);
            if seq.len() >= 2 {
                let head = &seq[..seq.len() - 1];
                assert!(
                    head.iter().all(|&c| c == head[0]),
                    "{t}: non-constant body {seq:?} (n={n} p={p})"
                );
            }
        }
    }
}

#[test]
fn prop_ss_always_one() {
    let seq = drain(Technique::Ss, 5000, 13, 1);
    assert!(seq.iter().all(|&c| c == 1));
    assert_eq!(seq.len(), 5000);
}

#[test]
fn prop_rand_within_bounds_any_np() {
    let mut rng = Rng::new(23);
    for _ in 0..20 {
        let n = 1000 + (rng.next_u64() % 200_000) as usize;
        let p = 2 + (rng.next_u64() % 128) as usize;
        let lo = (n / (100 * p)).max(1);
        let hi = (n / (2 * p)).max(lo);
        let seq = drain(Technique::Rand, n, p, rng.next_u64());
        // All but the remaining-clamped tail must respect the paper bounds.
        for (i, &c) in seq.iter().enumerate() {
            let is_tail = i + 1 == seq.len();
            assert!(
                (c >= lo && c <= hi) || is_tail,
                "RAND chunk {c} outside [{lo},{hi}] at {i} (n={n} p={p})"
            );
        }
    }
}

#[test]
fn prop_determinism_same_seed_same_schedule() {
    let mut rng = Rng::new(31);
    for _ in 0..10 {
        let n = 100 + (rng.next_u64() % 10_000) as usize;
        let p = 2 + (rng.next_u64() % 16) as usize;
        let seed = rng.next_u64();
        for t in Technique::ALL {
            let a = drain(t, n, p, seed);
            let b = drain(t, n, p, seed);
            assert_eq!(a, b, "{t} not deterministic");
        }
    }
}

#[test]
fn prop_chunk_counts_ordering() {
    // SS produces the most chunks (max overhead); STATIC the fewest
    // (≈ P); every dynamic technique sits in between.
    let n = 20_000;
    let p = 16;
    let ss = drain(Technique::Ss, n, p, 1).len();
    let stat = drain(Technique::Static, n, p, 1).len();
    assert_eq!(ss, n);
    assert_eq!(stat, p);
    for t in Technique::DYNAMIC {
        let c = drain(t, n, p, 1).len();
        assert!(c >= stat && c <= ss, "{t}: {c} chunks outside [{stat}, {ss}]");
    }
}

#[test]
fn prop_awf_weights_track_speed_ratio() {
    // Feed a 2-PE system with a constant 3x speed difference through many
    // noise-free chunks; learned weights must converge to ratio 3.
    use rdlb::dls::{AdaptiveWeightedFactoring, AwfVariant, ChunkCalculator};
    for variant in [AwfVariant::B, AwfVariant::C, AwfVariant::D, AwfVariant::E] {
        let mut awf = AdaptiveWeightedFactoring::new(2, variant);
        let mut remaining = 100_000usize;
        let mut idx = 0;
        while remaining > 0 && idx < 10_000 {
            let w = idx % 2;
            let c = awf.next_chunk(&ctx(100_000, 2, remaining, w, idx));
            let per_iter = if w == 0 { 1e-3 } else { 3e-3 };
            awf.feedback(&ChunkFeedback {
                worker: w,
                chunk_size: c,
                compute_time: c as f64 * per_iter,
                sched_overhead: 0.0,
                now: idx as f64,
                batch_done: false,
            });
            remaining -= c;
            idx += 1;
        }
        let w = awf.weights();
        let ratio = w[0] / w[1];
        assert!((ratio - 3.0).abs() < 0.2, "AWF-{variant:?}: ratio {ratio}");
    }
}
