//! Integration tests: the native (OS-thread, wall-clock) runtime with real
//! rust kernels and injected failures/perturbations.

use std::sync::Arc;
use std::time::Duration;

use rdlb::apps::{CostModel, MandelbrotApp, PsiaApp};
use rdlb::dls::Technique;
use rdlb::native::{ComputeBackend, NativeParams, NativeRuntime};

fn synthetic(n: usize, cost: f64) -> ComputeBackend {
    ComputeBackend::Synthetic { model: Arc::new(CostModel::from_costs(vec![cost; n])), scale: 1.0 }
}

#[test]
fn all_dynamic_techniques_complete_natively() {
    for technique in Technique::DYNAMIC {
        let p = NativeParams::new(128, 4, technique, true, synthetic(128, 5e-5));
        let o = NativeRuntime::new(p).unwrap().run().unwrap();
        assert!(o.completed(), "{technique}: {o:?}");
        assert_eq!(o.finished, 128, "{technique}");
    }
}

#[test]
fn real_mandelbrot_under_failures() {
    let app = MandelbrotApp { width: 64, height: 64, max_iter: 128, ..Default::default() };
    let mut p = NativeParams::new(
        app.n_tasks(),
        6,
        Technique::Fac,
        true,
        ComputeBackend::Mandelbrot(Arc::new(app)),
    );
    p = p.with_failures(5, 0.2); // P-1 of the compute threads die
    p.timeout = Duration::from_secs(60);
    let o = NativeRuntime::new(p).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    assert_eq!(o.finished, 64 * 64);
}

#[test]
fn real_psia_baseline() {
    let app = PsiaApp::synthetic_with(
        rdlb::apps::psia::PsiaParams { n_points: 256, img_size: 16, bin_size: 0.2 },
        512,
        3,
    );
    let p = NativeParams::new(512, 4, Technique::AwfC, true, ComputeBackend::Psia(Arc::new(app)));
    let o = NativeRuntime::new(p).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
}

#[test]
fn pe_perturbation_dilates_compute() {
    let mk = |slow: f64| {
        let mut p = NativeParams::new(64, 2, Technique::Ss, true, synthetic(64, 2e-3));
        p.slowdown[1] = slow;
        p.timeout = Duration::from_secs(60);
        NativeRuntime::new(p).unwrap().run().unwrap()
    };
    let clean = mk(1.0);
    let slowed = mk(4.0);
    assert!(clean.completed() && slowed.completed());
    assert!(
        slowed.parallel_time > clean.parallel_time,
        "slowdown had no effect: {} vs {}",
        slowed.parallel_time,
        clean.parallel_time
    );
}

#[test]
fn combined_perturbation_with_rdlb_completes_and_duplicates() {
    let mut p = NativeParams::new(96, 4, Technique::Fac, true, synthetic(96, 1e-3));
    p.slowdown[2] = 8.0;
    p.latency[2] = 0.1;
    p.timeout = Duration::from_secs(60);
    let o = NativeRuntime::new(p).unwrap().run().unwrap();
    assert!(o.completed(), "{o:?}");
    // The straggler's chunks should have been duplicated by idle PEs.
    assert!(o.stats.rescheduled_chunks > 0, "no rescheduling happened: {o:?}");
}

#[test]
fn hang_reported_not_deadlocked() {
    let mut p = NativeParams::new(64, 3, Technique::Gss, false, synthetic(64, 1e-3));
    p = p.with_failures(2, 0.01);
    p.timeout = Duration::from_millis(500);
    let t0 = std::time::Instant::now();
    let o = NativeRuntime::new(p).unwrap().run().unwrap();
    assert!(o.hung);
    assert!(t0.elapsed() < Duration::from_secs(5), "hang detection too slow");
}

#[test]
fn single_worker_executes_everything() {
    let p = NativeParams::new(50, 1, Technique::Gss, true, synthetic(50, 1e-4));
    let o = NativeRuntime::new(p).unwrap().run().unwrap();
    assert!(o.completed());
    assert_eq!(o.stats.finished_iterations, 50);
}
