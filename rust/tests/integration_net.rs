//! End-to-end tests of the distributed net runtime: full-protocol loopback
//! parity with the native runtime, real-socket runs, and the paper's
//! P−1-failure scenario across the wire.
//!
//! Every test that blocks on threads or sockets arms a [`Watchdog`]: a
//! deadlocked run fails within the guard's limit with a diagnostic naming
//! the test, instead of stalling `cargo test` to the CI job timeout.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rdlb::apps::{CostModel, MandelbrotApp};
use rdlb::dls::Technique;
use rdlb::native::{ComputeBackend, NativeParams, NativeRuntime};
use rdlb::net::{run_loopback, run_worker, serve_tcp, NetMasterParams, TcpTransport};
use rdlb::util::Watchdog;

fn synthetic(n: usize, cost: f64) -> ComputeBackend {
    ComputeBackend::Synthetic {
        model: Arc::new(CostModel::from_costs(vec![cost; n])),
        scale: 1.0,
    }
}

/// The whole protocol stack (codec included) over loopback produces the
/// same completion and the same result digest as the in-process native
/// runtime running the identical kernel.
#[test]
fn loopback_full_run_parity_with_native_runtime() {
    let _wd = Watchdog::arm("loopback_full_run_parity_with_native_runtime", Duration::from_secs(180));
    let app = MandelbrotApp { width: 32, height: 32, max_iter: 64, ..Default::default() };
    let n = app.n_tasks();
    let backend = ComputeBackend::Mandelbrot(Arc::new(app));

    let native = NativeRuntime::new(NativeParams::new(n, 4, Technique::Fac, true, backend.clone()))
        .unwrap()
        .run()
        .unwrap();
    let (net, reports) =
        run_loopback(NetMasterParams::new(n, 4, Technique::Fac, true), &backend).unwrap();

    assert!(native.completed(), "{native:?}");
    assert!(net.completed(), "{net:?}");
    assert_eq!(net.finished, native.finished);
    assert_eq!(net.n, native.n);
    // Escape-count digests are integer-valued, so the sums are exact and
    // must agree bit-for-bit across runtimes.
    assert_eq!(net.result_digest, native.result_digest, "digest parity across runtimes");
    assert_eq!(reports.len(), 4);
}

/// The paper's headline scenario across the wire protocol: P−1 of the
/// workers fail-stop mid-run and rDLB still finishes every iteration.
#[test]
fn tcp_p_minus_1_failures_complete_with_rdlb() {
    let _wd = Watchdog::arm("tcp_p_minus_1_failures_complete_with_rdlb", Duration::from_secs(180));
    let n = 600;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut params =
        NetMasterParams::new(n, 4, Technique::Fac, true).with_failures(3, 0.12).unwrap();
    params.timeout = Duration::from_secs(60);

    let server = std::thread::spawn(move || serve_tcp(listener, params, Duration::from_secs(10)));
    let workers: Vec<_> = (0..4)
        .map(|_| {
            let backend = synthetic(n, 1e-3);
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let transport = TcpTransport::connect(&addr).unwrap();
                run_worker(Box::new(transport), backend, "itest")
            })
        })
        .collect();

    let outcome = server.join().unwrap().unwrap();
    assert!(outcome.completed(), "rDLB must absorb P-1 failures: {outcome:?}");
    assert_eq!(outcome.finished, n);
    assert_eq!(outcome.failures, 3);
    assert!(outcome.stats.rescheduled_chunks > 0, "recovery must go through re-dispatch");

    let reports: Vec<_> = workers.into_iter().map(|j| j.join().unwrap().unwrap()).collect();
    assert_eq!(reports.iter().filter(|r| r.failed).count(), 3, "{reports:?}");
}

/// Without rDLB the same failures hang the run forever; the runtime bounds
/// the hang with the configured wall-clock timeout and reports it.
#[test]
fn failures_without_rdlb_hang_at_the_timeout_bound() {
    let _wd = Watchdog::arm("failures_without_rdlb_hang_at_the_timeout_bound", Duration::from_secs(120));
    let bound = Duration::from_millis(700);
    let mut params =
        NetMasterParams::new(600, 4, Technique::Fac, false).with_failures(3, 0.05).unwrap();
    params.timeout = bound;
    let t0 = Instant::now();
    let (outcome, _) = run_loopback(params, &synthetic(600, 1e-3)).unwrap();
    assert!(outcome.hung, "{outcome:?}");
    assert!(outcome.parallel_time.is_infinite());
    assert!(outcome.finished < 600);
    assert!(t0.elapsed() >= bound, "must wait out the full hang bound");
}
