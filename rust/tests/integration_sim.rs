//! Integration tests: simulated cluster end-to-end across the paper's
//! scenario classes, checking the qualitative *shapes* §4.2 reports.

use rdlb::apps::AppKind;
use rdlb::config::{ExperimentConfig, Scenario};
use rdlb::dls::Technique;
use rdlb::sim::{SimCluster, Topology};

fn cfg(app: AppKind, technique: Technique, pes: usize, n: usize) -> ExperimentConfig {
    ExperimentConfig::builder()
        .app(app)
        .tasks(n)
        .pes(pes)
        .technique(technique)
        .mean_cost(1e-3)
        .build()
        .unwrap()
}

fn run(cfg: &ExperimentConfig) -> rdlb::sim::Outcome {
    SimCluster::from_config(cfg).unwrap().run().unwrap()
}

#[test]
fn every_dynamic_technique_survives_every_failure_class() {
    // Fig. 3a/3b shape (i): with rDLB, 1, P/2 and P−1 failures all complete.
    let pes = 16;
    for technique in Technique::DYNAMIC {
        for failures in [1, pes / 2, pes - 1] {
            let mut c = cfg(AppKind::Uniform, technique, pes, 4000);
            c.scenario = Scenario::failures(failures);
            c.rdlb = true;
            let o = run(&c);
            assert!(
                o.completed(),
                "{technique} with {failures} failures did not complete: {o:?}"
            );
            assert_eq!(o.finished, 4000, "{technique}");
        }
    }
}

#[test]
fn without_rdlb_failures_hang_with_rdlb_not() {
    let pes = 16;
    for technique in [Technique::Fac, Technique::Gss, Technique::AwfB] {
        let mut c = cfg(AppKind::Uniform, technique, pes, 4000);
        c.scenario = Scenario::failures(pes / 2);
        c.rdlb = false;
        assert!(run(&c).hung, "{technique} must hang without rDLB");
        c.rdlb = true;
        assert!(run(&c).completed(), "{technique} must complete with rDLB");
    }
}

#[test]
fn single_failure_costs_little() {
    // Fig. 3 shape (ii): one failure ≈ baseline cost.
    let pes = 32;
    for technique in [Technique::Fac, Technique::AwfB, Technique::AwfC] {
        let base = {
            let c = cfg(AppKind::Psia, technique, pes, 8000);
            run(&c).parallel_time
        };
        let failed = {
            let mut c = cfg(AppKind::Psia, technique, pes, 8000);
            c.scenario = Scenario::failures(1);
            run(&c).parallel_time
        };
        assert!(
            failed < base * 1.6,
            "{technique}: 1 failure cost {failed} vs baseline {base}"
        );
    }
}

#[test]
fn small_chunks_more_robust_under_half_failures() {
    // Fig. 3/4 shape (iii): under P/2 failures, SS (smallest chunks) loses
    // less work than GSS (largest early chunks).
    let pes = 16;
    let time_of = |technique: Technique| {
        let mut total = 0.0;
        for seed in 0..5 {
            let mut c = cfg(AppKind::Uniform, technique, pes, 4000);
            c.scenario = Scenario::failures(pes / 2);
            c.seed = seed;
            let o = run(&c);
            assert!(o.completed());
            total += o.parallel_time;
        }
        total / 5.0
    };
    let ss = time_of(Technique::Ss);
    let gss = time_of(Technique::Gss);
    assert!(
        ss < gss * 1.5,
        "SS ({ss}) should not be much worse than GSS ({gss}) under P/2 failures"
    );
}

#[test]
fn p_minus_1_failures_serialize_on_master() {
    // Fig. 3 shape (iv): with P−1 failures the work is almost serialized.
    let pes = 8;
    let n = 2000;
    let mut c = cfg(AppKind::Uniform, Technique::Fac, pes, n);
    c.scenario = Scenario::failures(pes - 1);
    let o = run(&c);
    assert!(o.completed());
    let serial_estimate = n as f64 * 1e-3;
    assert!(
        o.parallel_time > serial_estimate * 0.5,
        "P-1 failures should approach serial time: {} vs {serial_estimate}",
        o.parallel_time
    );
}

#[test]
fn latency_perturbation_rdlb_speedup() {
    // Fig. 3c/d shape (v): under latency perturbation rDLB is faster.
    // The delay must be large relative to a chunk but smaller than the
    // makespan, so the perturbed node still receives work and its chunks
    // straggle (delay >= makespan would just exclude the node entirely
    // and the two modes would tie).
    let topo = Topology::new(4, 4);
    for technique in [Technique::AwfB, Technique::Fac] {
        let mk = |rdlb: bool| {
            let mut c = cfg(AppKind::Psia, technique, 16, 4000);
            c.nodes = topo.nodes;
            c.ranks_per_node = topo.ranks_per_node;
            c.scenario = Scenario::LatencyPerturb { node: 3, delay: 0.05 };
            c.rdlb = rdlb;
            run(&c)
        };
        let without = mk(false);
        let with = mk(true);
        assert!(without.completed() && with.completed());
        assert!(
            with.parallel_time < without.parallel_time,
            "{technique}: rDLB {} !< {}",
            with.parallel_time,
            without.parallel_time
        );
    }
}

#[test]
fn pe_perturbation_small_effect() {
    // Fig. 3 shape (vi): PE-availability perturbation alone has modest
    // impact on dynamically balanced runs.
    let mut c = cfg(AppKind::Mandelbrot, Technique::Fac, 16, 8192);
    c.nodes = 4;
    c.ranks_per_node = 4;
    let base = run(&c).parallel_time;
    c.scenario = Scenario::PePerturb { node: 3, factor: 0.5 };
    let pert = run(&c).parallel_time;
    assert!(pert < base * 2.0, "PE perturbation alone should be modest: {pert} vs {base}");
}

#[test]
fn static_is_not_rescheduled_but_dynamic_is() {
    // STATIC + failure = hang even with rDLB off; the paper excludes STATIC
    // from rDLB results. We verify STATIC still *works* in baseline.
    let c = cfg(AppKind::Uniform, Technique::Static, 8, 1000);
    assert!(run(&c).completed());
}

#[test]
fn mandelbrot_heavy_tail_hurts_static_more_than_fac() {
    // The motivation for DLS: high-variability workloads imbalance STATIC.
    let stat = run(&cfg(AppKind::Mandelbrot, Technique::Static, 16, 16_384)).parallel_time;
    let fac = run(&cfg(AppKind::Mandelbrot, Technique::Fac, 16, 16_384)).parallel_time;
    assert!(
        fac < stat,
        "FAC ({fac}) must beat STATIC ({stat}) on the heavy-tailed workload"
    );
}

#[test]
fn replications_differ_but_seeds_reproduce() {
    let mut c = cfg(AppKind::Exponential, Technique::Fac, 8, 2000);
    c.scenario = Scenario::failures(4);
    let a = SimCluster::new(c.sim_params(0).unwrap()).unwrap().run().unwrap();
    let b = SimCluster::new(c.sim_params(1).unwrap()).unwrap().run().unwrap();
    let a2 = SimCluster::new(c.sim_params(0).unwrap()).unwrap().run().unwrap();
    assert_eq!(a.parallel_time, a2.parallel_time, "same replication must reproduce");
    assert_ne!(a.parallel_time, b.parallel_time, "replications must differ");
}

#[test]
fn waste_bounded_in_healthy_runs() {
    // §3.2: rDLB adds no overhead to healthy executions — duplicate work
    // only appears in the tail and stays small.
    for technique in [Technique::Fac, Technique::Gss, Technique::AwfC] {
        let c = cfg(AppKind::Psia, technique, 16, 8000);
        let o = run(&c);
        assert!(
            o.waste_fraction() < 0.05,
            "{technique}: baseline waste {:.3}",
            o.waste_fraction()
        );
    }
}
