//! End-to-end tests of the bench subsystem: campaign execution on every
//! runtime, report round-tripping, seed determinism of the outcome metrics,
//! and regression gating against doctored baselines.

use rdlb::bench::{
    compare_reports, run_campaign, BenchScale, BenchSettings, CampaignReport, Thresholds,
};
use rdlb::config::RuntimeKind;

fn settings(runtimes: Vec<RuntimeKind>, seed: u64) -> BenchSettings {
    BenchSettings { runtimes, ..BenchSettings::new(BenchScale::smoke(), seed) }
}

#[test]
fn smoke_campaign_covers_all_three_runtimes() {
    let report = run_campaign(&settings(
        vec![RuntimeKind::Sim, RuntimeKind::Native, RuntimeKind::Net],
        1,
    ))
    .unwrap();
    for runtime in ["sim", "native", "net", "codec"] {
        assert!(
            report.cases.iter().any(|c| c.runtime == runtime),
            "no {runtime} case in {:?}",
            report.cases.iter().map(|c| &c.id).collect::<Vec<_>>()
        );
    }
    for case in &report.cases {
        assert!(!case.outcome.hung, "{} hung", case.id);
        assert_eq!(case.outcome.finished, case.outcome.n, "{} incomplete", case.id);
        assert!(case.wall.median_s >= 0.0 && case.wall.median_s.is_finite(), "{}", case.id);
        assert!(case.wall.tasks_per_s > 0.0, "{}", case.id);
        match case.runtime.as_str() {
            "sim" => {
                assert!(case.wall.events_per_s.unwrap_or(0.0) > 0.0, "{} has no events/s", case.id)
            }
            "codec" => {
                // The digest records the encoded payload size; round-trip
                // throughput is the gated signal.
                assert!(case.outcome.digest > 0.0, "{}", case.id);
                assert!(case.wall.events_per_s.unwrap_or(0.0) > 0.0, "{}", case.id);
            }
            _ => {
                // Wall-clock digests count every iteration exactly once
                // (Synthetic backend: 1.0 per task).
                assert_eq!(case.outcome.digest, case.outcome.n as f64, "{}", case.id);
            }
        }
    }
    // The contiguous-range Assign case is the O(1)-bytes witness: constant
    // 23-byte payload regardless of the chunk size baked into the id.
    let range_case = report
        .cases
        .iter()
        .find(|c| c.id.starts_with("codec/assign-range/"))
        .expect("codec range case present");
    assert_eq!(range_case.outcome.digest, 23.0);
    assert!(report.calibration_s > 0.0);
    assert!(report.sim_events_per_s().unwrap() > 0.0);
}

#[test]
fn report_json_round_trips_through_disk_format() {
    let report = run_campaign(&settings(vec![RuntimeKind::Sim], 3)).unwrap();
    let text = report.to_json_string();
    let back = CampaignReport::from_json_str(&text).unwrap();
    assert_eq!(back, report);
    // Comparing a campaign to itself always passes.
    let cmp = compare_reports(&back, &report, &Thresholds::default());
    assert!(cmp.passed(), "{}", cmp.summary());
}

#[test]
fn same_seed_identical_outcomes_different_seed_not() {
    let a = run_campaign(&settings(vec![RuntimeKind::Sim], 11)).unwrap();
    let b = run_campaign(&settings(vec![RuntimeKind::Sim], 11)).unwrap();
    assert_eq!(
        a.deterministic_digest(),
        b.deterministic_digest(),
        "same seed ⇒ identical outcome metrics (timestamps and wall excluded)"
    );
    let c = run_campaign(&settings(vec![RuntimeKind::Sim], 12)).unwrap();
    assert_ne!(a.deterministic_digest(), c.deterministic_digest());
}

#[test]
fn doctored_baseline_trips_the_gate() {
    let report = run_campaign(&settings(vec![RuntimeKind::Sim], 5)).unwrap();
    // Smoke cases can run under the default jitter floor; disable it so the
    // gate decision is purely about the doctored numbers.
    let thresholds = Thresholds { min_wall_s: 0.0, ..Thresholds::default() };

    // Baseline claims a sim case used to be 100× faster: wall regression.
    // (Pin the current median too, so timer granularity cannot zero it.)
    let mut current = report.clone();
    current.cases[0].wall.median_s = 1.0;
    let mut doctored = report.clone();
    doctored.cases[0].wall.median_s = 0.01;
    let cmp = compare_reports(&current, &doctored, &thresholds);
    assert!(!cmp.passed(), "wall doctoring must fail the gate:\n{}", cmp.summary());
    assert!(cmp.regressions.iter().any(|d| d.metric == "wall_median_s"));

    // Baseline claims 100× the simulator throughput: events/s regression.
    let mut doctored = report.clone();
    for case in &mut doctored.cases {
        if let Some(eps) = case.wall.events_per_s.as_mut() {
            *eps *= 100.0;
        }
    }
    let cmp = compare_reports(&report, &doctored, &thresholds);
    assert!(!cmp.passed(), "throughput doctoring must fail the gate:\n{}", cmp.summary());
    assert!(cmp.regressions.iter().any(|d| d.metric == "events_per_s"));

    // Baseline contains a case this campaign no longer runs: also a failure.
    let mut doctored = report.clone();
    let mut ghost = doctored.cases[0].clone();
    ghost.id = "sim/ghost/SS/baseline/p1/n1/rdlb".to_string();
    doctored.cases.push(ghost);
    let cmp = compare_reports(&report, &doctored, &thresholds);
    assert!(!cmp.passed());
    assert_eq!(cmp.missing_cases.len(), 1);
}
