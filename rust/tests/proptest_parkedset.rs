//! Property tests for `util::ParkedSet` against a naive `Vec`-based
//! reference model (proptest is unavailable offline; random op sequences
//! come from the in-tree PRNG).
//!
//! The master loops of all three runtimes depend on three properties:
//! insert/contains idempotence, order-preserving `drain_into`, and exact
//! agreement between the bitset (membership) and the insertion-order list
//! (iteration) across arbitrary interleavings of park/drain cycles.

use rdlb::util::{ParkedSet, Rng};

/// The obviously-correct reference: a Vec with linear scans.
#[derive(Default)]
struct NaiveSet {
    order: Vec<u32>,
}

impl NaiveSet {
    fn contains(&self, worker: usize) -> bool {
        self.order.contains(&(worker as u32))
    }

    fn insert(&mut self, worker: usize) -> bool {
        if self.contains(worker) {
            return false;
        }
        self.order.push(worker as u32);
        true
    }

    fn drain_into(&mut self, out: &mut Vec<u32>) {
        out.clear();
        std::mem::swap(&mut self.order, out);
    }

    fn len(&self) -> usize {
        self.order.len()
    }
}

/// Cross-check every observable of the two sets.
fn assert_agree(real: &ParkedSet, model: &NaiveSet, capacity: usize, ctx: &str) {
    assert_eq!(real.len(), model.len(), "{ctx}: len");
    assert_eq!(real.is_empty(), model.len() == 0, "{ctx}: is_empty");
    for w in 0..capacity {
        assert_eq!(real.contains(w), model.contains(w), "{ctx}: contains({w})");
    }
}

#[test]
fn random_op_sequences_match_the_naive_model() {
    let mut rng = Rng::new(0x9A7C_ED);
    // Capacities straddling the u64 bitset word boundaries.
    for &capacity in &[1usize, 5, 63, 64, 65, 128, 129, 200] {
        for round in 0..40 {
            let mut real = ParkedSet::new(capacity);
            let mut model = NaiveSet::default();
            let mut real_out = Vec::new();
            let mut model_out = Vec::new();
            for step in 0..200 {
                let ctx = format!("cap={capacity} round={round} step={step}");
                if rng.next_f64() < 0.85 {
                    let w = rng.gen_range(0, capacity as u64 - 1) as usize;
                    assert_eq!(real.insert(w), model.insert(w), "{ctx}: insert({w})");
                } else {
                    real.drain_into(&mut real_out);
                    model.drain_into(&mut model_out);
                    assert_eq!(real_out, model_out, "{ctx}: drain order");
                    assert!(real.is_empty(), "{ctx}: drained set must be empty");
                }
                assert_agree(&real, &model, capacity, &ctx);
            }
            // Final drain must surface exactly the surviving members, in
            // insertion order.
            real.drain_into(&mut real_out);
            model.drain_into(&mut model_out);
            assert_eq!(real_out, model_out, "cap={capacity} round={round}: final drain");
        }
    }
}

#[test]
fn insert_is_idempotent_under_repetition() {
    let mut rng = Rng::new(77);
    let mut set = ParkedSet::new(64);
    let mut firsts = 0usize;
    for _ in 0..1000 {
        let w = rng.gen_range(0, 15) as usize;
        if set.insert(w) {
            firsts += 1;
        }
        assert!(set.contains(w));
        assert!(!set.insert(w), "second insert of a present member must be a no-op");
    }
    assert_eq!(firsts, 16, "each of the 16 workers parks exactly once");
    assert_eq!(set.len(), 16);
}

#[test]
fn drain_preserves_order_across_repark_cycles() {
    let mut rng = Rng::new(0xD1CE);
    let mut set = ParkedSet::new(100);
    let mut out = Vec::new();
    for _ in 0..50 {
        // Park a random permutation prefix, then verify drain order.
        let k = rng.gen_range(1, 30) as usize;
        let mut expect = Vec::new();
        for _ in 0..k {
            let w = rng.gen_range(0, 99) as usize;
            if set.insert(w) {
                expect.push(w as u32);
            }
        }
        set.drain_into(&mut out);
        assert_eq!(out, expect, "drain must replay insertion order");
        // The drained buffer stays valid while re-parking (the wakeup-pass
        // pattern in the master loops).
        for &w in &out {
            assert!(set.insert(w as usize), "re-park after drain must succeed");
        }
        set.drain_into(&mut out);
        assert_eq!(out, expect);
    }
}

#[test]
fn bitset_and_list_agree_at_word_boundaries() {
    let mut set = ParkedSet::new(129);
    for w in [0usize, 63, 64, 65, 127, 128] {
        assert!(set.insert(w));
    }
    for w in 0..129 {
        let expected = matches!(w, 0 | 63 | 64 | 65 | 127 | 128);
        assert_eq!(set.contains(w), expected, "contains({w})");
    }
    let mut out = Vec::new();
    set.drain_into(&mut out);
    assert_eq!(out, vec![0, 63, 64, 65, 127, 128]);
    for w in 0..129 {
        assert!(!set.contains(w), "drain must clear every bit ({w})");
    }
}
