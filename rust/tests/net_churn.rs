//! Resource-stability test for the readiness-loop master under a
//! churner-heavy peer population: refused (stale-protocol) connections must
//! be deregistered from the poll set the moment their goodbye flushes, with
//! their fd closed and their scratch buffers reclaimed by the pool.
//!
//! This lives in its own test binary on purpose: it asserts on the
//! process-global [`open_conn_gauge`] / [`frame_buffer_allocs`] hooks, and
//! concurrent net tests in the same process would perturb them.

use std::sync::Arc;
use std::time::Duration;

use rdlb::apps::CostModel;
use rdlb::dls::Technique;
use rdlb::native::ComputeBackend;
use rdlb::net::master::{frame_buffer_allocs, open_conn_gauge};
use rdlb::net::{
    run_worker, Frame, LoopbackTransport, NetMaster, NetMasterParams, Transport, WorkerHello,
    PROTOCOL_VERSION,
};
use rdlb::util::Watchdog;

/// One good worker and 33 stale-version churners.  The run must complete,
/// every churner's fd must be gone by the time the master returns, and the
/// buffer pool must have absorbed the frame traffic: total pool-miss
/// allocations stay O(P) while the frames exchanged are O(chunks) >> P.
#[test]
fn refused_churners_leak_no_fds_and_no_buffers() {
    let _wd = Watchdog::arm("refused_churners_leak_no_fds_and_no_buffers", Duration::from_secs(180));
    let n = 2000;
    let p = 34;
    let conns_before = open_conn_gauge();
    let allocs_before = frame_buffer_allocs();

    let mut params = NetMasterParams::new(n, p, Technique::Fac, true);
    params.timeout = Duration::from_secs(60);
    let backend = ComputeBackend::Synthetic {
        model: Arc::new(CostModel::from_costs(vec![1e-5; n])),
        scale: 1.0,
    };

    let mut connections: Vec<Box<dyn Transport>> = Vec::with_capacity(p);
    let mut joins: Vec<std::thread::JoinHandle<anyhow::Result<bool>>> = Vec::with_capacity(p);
    for w in 0..p {
        let (master_end, worker_end) = LoopbackTransport::pair();
        connections.push(Box::new(master_end));
        if w == 0 {
            let b = backend.clone();
            joins.push(std::thread::spawn(move || {
                run_worker(Box::new(worker_end), b, "survivor").map(|_| true)
            }));
        } else {
            // A churner: stale Hello, expect Terminate, hang up.
            joins.push(std::thread::spawn(move || {
                let (mut tx, mut rx) = Box::new(worker_end).split()?;
                tx.send(&Frame::Hello(WorkerHello {
                    version: PROTOCOL_VERSION - 1,
                    backend: "stale".into(),
                }))?;
                Ok(matches!(rx.recv(), Ok(Frame::Terminate)))
            }));
        }
    }

    let outcome = NetMaster::new(params).unwrap().run(connections).unwrap();
    assert!(outcome.completed(), "{outcome:?}");
    assert_eq!(outcome.finished, n);
    assert_eq!(outcome.stats.refused_workers, (p - 1) as u64, "{:?}", outcome.stats);
    assert_eq!(outcome.failures, 0, "a refusal is not an injected failure");
    for (w, join) in joins.into_iter().enumerate() {
        let got_goodbye = join.join().unwrap().unwrap();
        assert!(got_goodbye, "worker {w} must see Terminate (churner) or finish (survivor)");
    }

    // Every fd the master registered is deregistered again.
    assert_eq!(
        open_conn_gauge(),
        conns_before,
        "refused/terminated fds must leave the poll set and close"
    );
    // Fac at P=34 over n=2000 exchanges hundreds of Assign/Request frames
    // with the survivor; if closed connections really recycle their
    // buffers through the pool, allocations stay bounded by the pool's
    // working set (~3 buffers per connection), not by frame count.
    let alloc_growth = frame_buffer_allocs() - allocs_before;
    assert!(
        alloc_growth <= (3 * p + 16) as u64,
        "buffer allocations must be O(P), not O(frames): grew by {alloc_growth}"
    );
    assert!(
        outcome.stats.completed_chunks > alloc_growth,
        "sanity: the run exchanged more frames ({} chunks) than buffers allocated \
         ({alloc_growth}) — otherwise the bound above proves nothing",
        outcome.stats.completed_chunks
    );
}
