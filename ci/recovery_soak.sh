#!/usr/bin/env bash
# Kill/resume soak for the journaled distributed master.
#
# Runs one uninterrupted `rdlb serve --spawn-local` reference run, then the
# same workload under `--journal-dir`, kill -9s the master at KILLS points
# (triggered by write-ahead journal growth, so the kills land mid-run on
# any machine speed), resumes each time with `rdlb serve --resume`, and
# asserts the recovered run completes with the reference run's digest —
# which the chaos oracle already pins to the serial kernel's, so digest
# parity here means no iteration was lost or double-counted across crashes.
#
# Knobs (env, with defaults): BIN=target/release/rdlb TECHNIQUE=fac
# KILLS=2 WORKERS=4 TASKS=65536 MAX_ITER=800000 GROW=2048 SOAK_DIR=<mktemp>
#
# Exit 0 only if: every kill that landed was followed by a successful
# resume, at least one kill landed mid-run, the final session printed a
# RESULT digest, and that digest equals the uninterrupted reference's.
set -euo pipefail

BIN=${BIN:-target/release/rdlb}
TECHNIQUE=${TECHNIQUE:-fac}
KILLS=${KILLS:-2}
WORKERS=${WORKERS:-4}
TASKS=${TASKS:-65536}
MAX_ITER=${MAX_ITER:-800000}
# Journal bytes that must be appended between kill points.
GROW=${GROW:-2048}
WORK=${SOAK_DIR:-$(mktemp -d)}
DIR="$WORK/wal"
mkdir -p "$WORK"

say() { printf '\nsoak: %s\n' "$*"; }

PID=""
cleanup() {
    [ -n "$PID" ] && kill -9 "$PID" 2>/dev/null
    # Orphaned --reconnect workers outlive a killed master by design; don't
    # leave them polling a dead port after the soak itself is over.
    pkill -f "rdlb worker --connect" 2>/dev/null
    say "logs kept in $WORK"
}
trap 'cleanup || true' EXIT

common=(--app mandelbrot --technique "$TECHNIQUE" --tasks "$TASKS"
    --spawn-local "$WORKERS" --max-iter "$MAX_ITER" --timeout 300)

say "reference run (uninterrupted): technique=$TECHNIQUE tasks=$TASKS workers=$WORKERS"
"$BIN" serve "${common[@]}" | tee "$WORK/ref.log"
REF=$(grep -o 'digest=[0-9.-]*' "$WORK/ref.log" | tail -1)
if [ -z "$REF" ]; then
    say "FAIL: reference run produced no digest"
    exit 1
fi

say "journaled run: killing the master at $KILLS points ($GROW journal bytes apart)"
"$BIN" serve "${common[@]}" --journal-dir "$DIR" >"$WORK/run0.log" 2>&1 &
PID=$!

jsize() { stat -c %s "$DIR/journal.bin" 2>/dev/null || echo 0; }

landed=0
for i in $(seq 1 "$KILLS"); do
    target=$(($(jsize) + GROW))
    while kill -0 "$PID" 2>/dev/null && [ "$(jsize)" -lt "$target" ]; do
        sleep 0.2
    done
    if ! kill -9 "$PID" 2>/dev/null; then
        say "run completed before kill $i could land (raise MAX_ITER to stretch the run)"
        break
    fi
    wait "$PID" 2>/dev/null || true
    landed=$i
    say "kill $i landed at journal size $(jsize) — resuming"
    "$BIN" serve --resume "$DIR" >"$WORK/run$i.log" 2>&1 &
    PID=$!
done

wait "$PID" || true
PID=""
for f in "$WORK"/run*.log; do
    printf '\n===== %s =====\n' "$f"
    cat "$f"
done

if [ "$landed" -lt 1 ]; then
    say "FAIL: no kill landed mid-run — the soak never exercised recovery"
    exit 1
fi
if ! grep -q "resumed epoch" "$WORK/run$landed.log"; then
    say "FAIL: resume $landed is missing the recovery banner"
    exit 1
fi

FINAL=$(grep -ho 'digest=[0-9.-]*' "$WORK"/run*.log | tail -1)
say "reference $REF vs recovered ${FINAL:-<none>}"
if [ -z "$FINAL" ]; then
    say "FAIL: no RESULT digest after recovery (hung or crashed run)"
    exit 1
fi
if [ "$FINAL" != "$REF" ]; then
    say "FAIL: digest parity broken after $landed kill(s): $FINAL != $REF"
    exit 1
fi
say "PASS: $landed kill -9(s) survived with digest parity ($REF)"
