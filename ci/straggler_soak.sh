#!/usr/bin/env bash
# Straggler soak for the worker-health layer.
#
# Runs one uninterrupted `rdlb serve --spawn-local` reference run, then the
# same workload with `--health` armed, SIGSTOPs one worker process mid-run
# (a real OS-level straggler: the whole process freezes, heartbeats
# included), and asserts the run still completes in bounded time with the
# reference digest — i.e. the overdue chunk was speculatively re-dispatched
# to a healthy worker and the straggler's late/lost work neither hangs the
# run nor corrupts the result.
#
# Knobs (env, with defaults): BIN=target/release/rdlb TECHNIQUE=fac
# WORKERS=4 TASKS=65536 MAX_ITER=800000 STOP_AFTER=1.0 SOAK_DIR=<mktemp>
#
# Exit 0 only if: the stop landed while the run was still going, the
# worker-health banner shows the layer was armed, the run printed a
# non-HUNG RESULT with rescheduled > 0, and its digest equals the
# uninterrupted reference's.
set -euo pipefail

BIN=${BIN:-target/release/rdlb}
TECHNIQUE=${TECHNIQUE:-fac}
WORKERS=${WORKERS:-4}
TASKS=${TASKS:-65536}
MAX_ITER=${MAX_ITER:-800000}
# Seconds to wait after all worker processes exist before freezing one
# (covers registration; by then every worker is holding a chunk).
STOP_AFTER=${STOP_AFTER:-1.0}
WORK=${SOAK_DIR:-$(mktemp -d)}
mkdir -p "$WORK"

say() { printf '\nsoak: %s\n' "$*"; }

PID=""
FROZEN=""
cleanup() {
    [ -n "$FROZEN" ] && { kill -CONT "$FROZEN" 2>/dev/null; kill -9 "$FROZEN" 2>/dev/null; }
    [ -n "$PID" ] && kill -9 "$PID" 2>/dev/null
    pkill -f "rdlb worker --connect" 2>/dev/null
    say "logs kept in $WORK"
}
trap 'cleanup || true' EXIT

common=(--app mandelbrot --technique "$TECHNIQUE" --tasks "$TASKS"
    --spawn-local "$WORKERS" --max-iter "$MAX_ITER" --timeout 300)

say "reference run (no straggler): technique=$TECHNIQUE tasks=$TASKS workers=$WORKERS"
"$BIN" serve "${common[@]}" | tee "$WORK/ref.log"
REF=$(grep -o 'digest=[0-9.-]*' "$WORK/ref.log" | tail -1)
if [ -z "$REF" ]; then
    say "FAIL: reference run produced no digest"
    exit 1
fi

say "health-armed run: freezing one worker with SIGSTOP mid-run"
"$BIN" serve "${common[@]}" --health --health-tick 0.2 >"$WORK/run.log" 2>&1 &
PID=$!

# Wait for all forked workers to exist, give them a beat to register and
# pick up their first chunks, then freeze the last one.
for _ in $(seq 1 100); do
    [ "$(pgrep -cf 'rdlb worker --connect' || true)" -ge "$WORKERS" ] && break
    if ! kill -0 "$PID" 2>/dev/null; then
        say "FAIL: master exited before its workers appeared"
        exit 1
    fi
    sleep 0.1
done
sleep "$STOP_AFTER"
FROZEN=$(pgrep -f 'rdlb worker --connect' | tail -1)
if [ -z "$FROZEN" ] || ! kill -0 "$PID" 2>/dev/null; then
    say "FAIL: run finished before the straggler could be frozen (raise TASKS/MAX_ITER)"
    exit 1
fi
kill -STOP "$FROZEN"
say "worker pid $FROZEN frozen — waiting for the master to route around it"

wait "$PID" || true
PID=""
printf '\n===== %s =====\n' "$WORK/run.log"
cat "$WORK/run.log"

if ! grep -q "worker-health armed" "$WORK/run.log"; then
    say "FAIL: the worker-health banner is missing — the layer never armed"
    exit 1
fi
if grep -q "RESULT: HUNG" "$WORK/run.log"; then
    say "FAIL: run hung despite the health layer (straggler never routed around)"
    exit 1
fi
FINAL=$(grep -o 'digest=[0-9.-]*' "$WORK/run.log" | tail -1)
say "reference $REF vs straggler run ${FINAL:-<none>}"
if [ -z "$FINAL" ]; then
    say "FAIL: no RESULT digest (crashed run?)"
    exit 1
fi
if [ "$FINAL" != "$REF" ]; then
    say "FAIL: digest parity broken by speculative re-dispatch: $FINAL != $REF"
    exit 1
fi
RESCHED=$(grep -o 'rescheduled=[0-9]*' "$WORK/run.log" | tail -1 | cut -d= -f2)
if [ "${RESCHED:-0}" -lt 1 ]; then
    say "FAIL: rescheduled=${RESCHED:-0} — the frozen worker's chunk was never speculated"
    exit 1
fi
say "PASS: straggler routed around (rescheduled=$RESCHED) with digest parity ($REF)"
